"""Property-testing shim: real ``hypothesis`` when installed, otherwise a
tiny deterministic fallback so the property suites still execute.

The seed image does not ship ``hypothesis`` (CI pins it, laptops may not).
``pytest.importorskip`` would silently drop the whole module — including its
purely deterministic tests — so instead the strategy combinators used by this
repo (``integers``, ``sampled_from``, ``booleans``, ``floats``) are
re-implemented as seeded samplers and ``@given`` becomes "run the test body
over N deterministic draws". Shrinking/edge-case search is lost, but every
property still gets exercised on a reproducible sample.

Usage in test modules::

    from _propcheck import given, settings, st
"""
from __future__ import annotations

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random
    import types

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                               booleans=_booleans, floats=_floats)

    def settings(**_kw):
        return lambda fn: fn

    def given(*strategies):
        def deco(fn):
            def run():
                rng = random.Random(0xEF7A)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(*(s.draw(rng) for s in strategies))
            # NOT functools.wraps: copying __wrapped__ would make pytest
            # unwrap to the original signature and hunt for fixtures.
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco
