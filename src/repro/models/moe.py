"""Mixture-of-Experts FFN with expert parallelism.

Dispatch is sort-free-capacity ("bucketed scatter"): assignments are grouped
by expert with one argsort, ranked by position, and scattered into a static
(E_local, capacity, d) buffer — no GShard (tokens, E, capacity) dispatch
einsum (which is FLOPs-catastrophic at 128-384 experts), and no ragged shapes.

Expert parallelism (EP) maps experts onto the ``model`` mesh axis via
``shard_map``: activations arrive replicated across ``model`` (Megatron
pattern), each device filters the assignments that hit its local experts,
computes, and one ``psum`` over ``model`` combines — the only collective in
the layer. Load is balanced in expectation (tokens hash uniformly over E).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoECfg
from repro.utils.compat import shard_map
from repro.models.layers import ACTS, dense_init


def moe_init(key, d: int, m: MoECfg, dtype):
    ks = jax.random.split(key, 4)
    e, f = m.num_experts, m.expert_d_ff
    scale = 1.0 / (d ** 0.5)
    def ew(k, a, b):
        return (jax.random.normal(k, (e, a, b), jnp.float32) / (a ** 0.5)).astype(dtype)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=scale),
        "wg": ew(ks[1], d, f),
        "wu": ew(ks[2], d, f),
        "wd": ew(ks[3], f, d),
    }


def _moe_local(x2d, router_w, wg, wu, wd, *, top_k: int, e_start,
               e_count: int, capacity: int, act: str, num_experts: int):
    """Route + dispatch + compute for experts [e_start, e_start+e_count).

    x2d: (N, d). Returns (y (N, d), aux_loss scalar).
    """
    n, d = x2d.shape
    a = ACTS[act]
    logits = jnp.matmul(x2d.astype(jnp.float32), router_w)       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)                      # (N, k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    top1 = idx[:, 0]
    f_e = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(f_e * p_e)

    eid = idx.reshape(-1)
    tid = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)
    w = vals.reshape(-1)
    local = (eid >= e_start) & (eid < e_start + e_count)
    eid_l = jnp.where(local, eid - e_start, e_count).astype(jnp.int32)

    order = jnp.argsort(eid_l)                                    # group by expert
    eid_s = eid_l[order]
    tid_s = tid[order]
    w_s = w[order]
    starts = jnp.searchsorted(eid_s, jnp.arange(e_count + 1, dtype=jnp.int32))
    rank = jnp.arange(n * top_k, dtype=jnp.int32) - starts[
        jnp.clip(eid_s, 0, e_count)]
    keep = (eid_s < e_count) & (rank < capacity)
    slot = jnp.where(keep, eid_s * capacity + rank, e_count * capacity)

    buf = jnp.zeros((e_count * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].set(x2d[tid_s], mode="drop")
    h = buf[: e_count * capacity].reshape(e_count, capacity, d)
    hidden = a(jnp.einsum("ecd,edf->ecf", h, wg,
                          preferred_element_type=jnp.float32).astype(x2d.dtype))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", h, wu,
                                 preferred_element_type=jnp.float32).astype(x2d.dtype)
    y_buf = jnp.einsum("ecf,efd->ecd", hidden, wd,
                       preferred_element_type=jnp.float32).astype(x2d.dtype)
    y_buf = jnp.concatenate(
        [y_buf.reshape(e_count * capacity, d),
         jnp.zeros((1, d), x2d.dtype)], axis=0)
    contrib = y_buf[slot] * jnp.where(keep, w_s, 0.0)[:, None].astype(x2d.dtype)
    y = jnp.zeros((n, d), x2d.dtype).at[tid_s].add(contrib)
    return y, aux


def moe_apply(params, x, m: MoECfg, *, act: str = "silu",
              mesh=None, ep_axis: str = "model",
              dp_axes: tuple = ("pod", "data"), mode: str = "train"):
    """x: (B, S, d) -> (y, aux_loss). EP over ``ep_axis`` when a mesh with
    that axis (size > 1) is active; single-device path otherwise.

    Decode inference uses the all-device EP layout (inference_ep): expert
    weights stay fully sharded (E over 'data', ff over 'model'); the few
    decode tokens are all-gathered instead of gathering GBs of weights.
    """
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    e = m.num_experts
    n_tokens = b * s

    ep = (mesh is not None and ep_axis in mesh.shape and mesh.shape[ep_axis] > 1)
    if (ep and m.inference_ep and mode == "decode"
            and "data" in mesh.shape and e % mesh.shape["data"] == 0):
        return _moe_inference_ep(params, x2, m, mesh=mesh, act=act,
                                 dp_axes=dp_axes, shape=(b, s, d))
    if not ep:
        cap = max(4, math.ceil(n_tokens * m.top_k / e * m.capacity_factor))
        y, aux = _moe_local(
            x2, params["router"], params["wg"], params["wu"], params["wd"],
            top_k=m.top_k, e_start=0, e_count=e, capacity=cap, act=act,
            num_experts=e)
        return y.reshape(b, s, d), aux

    msize = mesh.shape[ep_axis]
    e_count = e // msize
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    n_local = n_tokens // dp_size
    cap = max(4, math.ceil(n_local * m.top_k / e * m.capacity_factor))
    fsdp = "data" if "data" in mesh.shape and mesh.shape["data"] > 1 else None

    def inner(rw, wg, wu, wd, xl):
        me = jax.lax.axis_index(ep_axis)
        if fsdp is not None:
            # ZeRO-3 just-in-time gather of this device's expert shard along
            # the FSDP axis (weights stored P("model", "data", None)).
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=1, tiled=True)
        y, aux = _moe_local(
            xl, rw, wg, wu, wd, top_k=m.top_k, e_start=me * e_count,
            e_count=e_count, capacity=cap, act=act, num_experts=e)
        y = jax.lax.psum(y, ep_axis)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    wspec = P(ep_axis, fsdp, None)
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(), wspec, wspec, wspec, P(dp if dp else None)),
        out_specs=(P(dp if dp else None), P()),
        check_vma=False,
    )(params["router"], params["wg"], params["wu"], params["wd"], x2)
    return y.reshape(b, s, d), aux


def _moe_inference_ep(params, x2, m: MoECfg, *, mesh, act, dp_axes, shape):
    """Decode-path MoE: experts sharded E-over-'data' x ff-over-'model';
    tokens replicated (all-gather of KBs); single psum combines. No weight
    gathers — the collective-bytes hillclimb for decode_32k (§Perf)."""
    b, s, d = shape
    e = m.num_experts
    d_size = mesh.shape["data"]
    e_count = e // d_size
    n = x2.shape[0]
    cap = max(4, math.ceil(n * m.top_k / e * m.capacity_factor))

    def inner(rw, wg, wu, wd, xl):
        di = jax.lax.axis_index("data")
        y, aux = _moe_local(
            xl, rw, wg, wu, wd, top_k=m.top_k, e_start=di * e_count,
            e_count=e_count, capacity=cap, act=act, num_experts=e)
        # wd's contraction dim (ff) is sharded over 'model': partial sums —
        # one psum over (data, model) combines expert shards and partials.
        y = jax.lax.psum(y, ("data", "model"))
        return y, aux

    wspec_in = P("data", None, "model")   # wg, wu: (E@data, d, ff@model)
    wspec_out = P("data", "model", None)  # wd:     (E@data, ff@model, d)
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(), wspec_in, wspec_in, wspec_out, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )(params["router"], params["wg"], params["wu"], params["wd"], x2)
    return y.reshape(b, s, d), aux
