"""Pytree helpers shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_with_names(tree):
    """Flatten a pytree into (dotted_name, leaf) pairs, stable order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        dt = getattr(x, "dtype", jnp.float32)
        total += int(np.prod(x.shape)) * jnp.dtype(dt).itemsize
    return total


def cast_floating(tree, dtype):
    """Cast floating-point leaves of a pytree to `dtype`."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))
