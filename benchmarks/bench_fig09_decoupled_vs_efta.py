"""Paper Fig. 9: end-to-end FT attention vs decoupled FT attention across
sequence lengths (batch adjusted for constant token count), plus the
intermediate-memory blowup that OOMs the decoupled path at 16k."""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, qkv, time_fn
from repro.core import EFTAConfig, decoupled_ft_attention, decoupled_memory_bytes
from repro.core.efta import efta_attention

TOTAL_TOKENS = 2048   # paper: 16k; scaled for the CPU host
HEADS, DIM = 4, 64
# NOTE: the paper's 3.7-7.5x comes from GPU kernel-launch + HBM round-trip
# costs that a CPU host hides (XLA fuses aggressively and "launches" are
# function calls); the structural wins that DO show here are the monotone
# speedup growth with sequence length and the quadratic S/P footprint that
# OOMs the decoupled path (the 16k row). On TPU, the S/P HBM traffic is the
# dominant term — quantified in EXPERIMENTS.md §Perf cell C (23.4 TB/device).


def run():
    rows = []
    for seq in (128, 256, 512, 1024):
        b = TOTAL_TOKENS // seq
        q, k, v = qkv(b, HEADS, HEADS, seq, DIM, jnp.float32)
        cfg = EFTAConfig(mode="correct", stride=16, block_kv=128)
        efta = jax.jit(functools.partial(efta_attention, cfg=cfg))
        t_efta = time_fn(lambda: efta(q, k, v))
        t_dec = time_fn(lambda: decoupled_ft_attention(q, k, v))
        rows.append({"name": f"efta_seq{seq}", "us": t_efta * 1e6,
                     "derived": f"speedup={t_dec/t_efta:.2f}x"})
        rows.append({"name": f"decoupled_seq{seq}", "us": t_dec * 1e6,
                     "derived": f"S+P bytes={decoupled_memory_bytes(b, HEADS, seq, seq):.0f}"})
    # the paper's OOM point: decoupled intermediate footprint at 16k on 40GB
    rows.append({"name": "decoupled_16k_SP_bytes", "us": 0.0,
                 "derived": f"{decoupled_memory_bytes(1, 32, 16384, 16384)/1e9:.1f}GB>40GB:OOM"})
    emit(rows, "Fig9: EFTA vs decoupled FT attention")
    return rows


if __name__ == "__main__":
    run()
