"""Soft-error (single-event-upset) injection via bit flips.

The paper's error model is a single bit flip per protected region (SEU
assumption, §4.2). There is no faulty hardware in CI, so faults are *injected*
at named sites inside the attention pipeline and the framework must detect and
correct them. Sites mirror the paper's Cases:

  GEMM1    — after the Q·Kᵀ accumulate (Case: ABFT on GEMM I)
  ROWMAX   — in the running row max (Case 1: cancels analytically)
  EXP      — after exp(S - m)        (Case 2: checksum-reuse + recompute)
  ROWSUM   — in the running row sum  (Case 3: SNVR range restriction)
  GEMM2    — after the P·V accumulate (ABFT on GEMM II, unified verification)
  WEIGHTS  — in model weights (memory fault; used by model-level benches)
  KV       — in resident paged KV-cache blocks (HBM memory fault between
             decode steps; detected at read time by the block checksums —
             at gather time on the ``gather`` backend, inside the fused
             paged-attention kernel's KV streaming loop on the ``fused``
             backend — and repaired by block re-prefill). For this site the
             FaultSpec coordinates are reinterpreted as (batch=layer,
             block=pool block id, head=kv head, row=in-block offset,
             col=head-dim feature).
"""
from __future__ import annotations

import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Site(enum.IntEnum):
    NONE = -1
    GEMM1 = 0
    ROWMAX = 1
    EXP = 2
    ROWSUM = 3
    GEMM2 = 4
    WEIGHTS = 5
    KV = 6


class FaultSpec(NamedTuple):
    """A (batch of) injected single-bit faults. All fields are int32 arrays of
    shape (n_faults,). ``site == Site.NONE`` disables an entry. ``block`` is
    the KV-block iteration index at which the flip occurs (-1 = every block's
    first touch? no — -1 matches block 0)."""

    site: jax.Array
    block: jax.Array
    batch: jax.Array
    head: jax.Array
    row: jax.Array
    col: jax.Array
    bit: jax.Array

    @staticmethod
    def none(n: int = 1) -> "FaultSpec":
        z = jnp.full((n,), -1, dtype=jnp.int32)
        return FaultSpec(z, z * 0, z * 0, z * 0, z * 0, z * 0, z * 0)

    @staticmethod
    def single(site: Site, *, block: int = 0, batch: int = 0, head: int = 0,
               row: int = 0, col: int = 0, bit: int = 20) -> "FaultSpec":
        def a(v):
            return jnp.asarray([v], dtype=jnp.int32)
        return FaultSpec(a(int(site)), a(block), a(batch), a(head), a(row), a(col), a(bit))


def _uint_dtype(dtype) -> jnp.dtype:
    return {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[jnp.dtype(dtype).itemsize]


def flip_bit_at(x: jax.Array, flat_index: jax.Array, bit: jax.Array) -> jax.Array:
    """Flip one bit of the element at ``flat_index`` of ``x`` (any float dtype)."""
    ui = _uint_dtype(x.dtype)
    nbits = jnp.dtype(ui).itemsize * 8
    bit = jnp.clip(bit, 0, nbits - 1).astype(ui)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), ui)
    cur = flat[flat_index]
    flat = flat.at[flat_index].set(cur ^ (ui(1) << bit))
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def inject(x: jax.Array, fault: FaultSpec | None, site: Site,
           block_index: jax.Array | int = 0) -> jax.Array:
    """Apply every matching fault in ``fault`` to tensor ``x``.

    ``x`` is indexed as (batch, head, row[, col]); vector sites (ROWMAX/ROWSUM)
    ignore ``col``. Out-of-range coordinates are clamped (still a valid SEU).
    """
    if fault is None:
        return x
    n = fault.site.shape[0]
    block_index = jnp.asarray(block_index, dtype=jnp.int32)
    for i in range(n):  # n is small & static — unrolled
        match = (fault.site[i] == int(site)) & (fault.block[i] == block_index)
        x = jax.lax.cond(match, lambda t: _flip_one(t, fault, i), lambda t: t, x)
    return x


def _flip_one(x: jax.Array, fault: FaultSpec, i: int) -> jax.Array:
    shape = x.shape
    # Clamp coordinates into range.
    idx = []
    coords = [fault.batch[i], fault.head[i], fault.row[i], fault.col[i]]
    for dim, c in zip(shape, coords):
        idx.append(jnp.clip(c, 0, dim - 1).astype(jnp.int32))
    # Build the flat index for the leading len(idx) dims.
    flat = jnp.int32(0)
    stride = 1
    for dim in shape[len(idx):]:
        stride *= dim
    strides = []
    s = stride
    for dim in reversed(shape[: len(idx)]):
        strides.append(s)
        s *= dim
    strides = list(reversed(strides))
    for c, st in zip(idx, strides):
        flat = flat + c * jnp.int32(st)
    return flip_bit_at(x, flat, fault.bit[i])


def random_fault(rng: np.random.Generator, *, sites, shape_bhsc, n_blocks: int,
                 max_bit: int = 31) -> FaultSpec:
    """Sample a uniform random single fault (host-side, for campaigns)."""
    b, h, s, c = shape_bhsc
    site = int(rng.choice([int(x) for x in sites]))
    return FaultSpec.single(
        Site(site),
        block=int(rng.integers(0, max(n_blocks, 1))),
        batch=int(rng.integers(0, b)),
        head=int(rng.integers(0, h)),
        row=int(rng.integers(0, s)),
        col=int(rng.integers(0, c)),
        bit=int(rng.integers(0, max_bit + 1)),
    )
