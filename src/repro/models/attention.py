"""Attention block: GQA/MQA + RoPE + sliding window + cross-attention, with
the paper's EFTA as the attention implementation.

The KV cache uses slot = position % cache_len, which uniformly covers:
  * global layers  (cache_len = max_len, slot = position)
  * sliding window (cache_len = window,  ring buffer)
Keys are cached post-RoPE, so ring wraparound needs no re-rotation; masking
only needs ``kv_len`` (number of valid slots).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg, FTCfg
from repro.core import checksum as cks
from repro.core.efta import EFTAConfig, FTReport
from repro.kernels.efta_paged import efta_paged_attention_pallas
from repro.kernels.ops import attention as attention_op
from repro.models.layers import dense_init, matmul, rope


class KVCache(NamedTuple):
    k: jax.Array            # (B, Hkv, cache_len, hd)
    v: jax.Array
    pos: jax.Array          # int32 scalar: number of tokens seen so far
    # cross-attention memory (computed once at prefill; empty arrays if unused)
    ck: jax.Array
    cv: jax.Array


class PagedKVCache(NamedTuple):
    """One layer's view of the paged serve engine's checksummed block pool.

    Passed in place of :class:`KVCache` to run the unified batched step over
    ragged requests through the fused paged-attention kernel: K/V stay in the
    shared pool and are consumed by block table, never gathered into a
    contiguous view. The step is *multi-token*: each request feeds a chunk of
    ``q_len`` rows (1 = decode, up to the chunk width = prefill / extend /
    repair), so one mixed batch serves every regime through one compiled
    program. ``bad`` is an *output* plane: per-(request, table-slot)
    resident-checksum mismatches found this step (in-kernel for streamed
    blocks, at append time for partially-overwritten blocks), which the
    engine's repair path consumes. Stacked over layers for the transformer's
    block scan.
    """

    k: jax.Array     # (num_blocks+1, Hkv, block_size, hd); row 0 = null block
    v: jax.Array
    kc1: jax.Array   # (num_blocks+1, Hkv, check_stride, hd) resident encode_kv
    kc2: jax.Array
    vc1: jax.Array
    vc2: jax.Array
    bt: jax.Array    # (B, table_len) int32 per-request block tables (0-padded)
    pos: jax.Array   # (B,) int32 tokens resident before this step
    q_len: jax.Array  # (B,) int32 valid chunk rows this step (0 = idle slot)
    bad: jax.Array   # (B, table_len) int32 mismatch flags (in/out)


def efta_cfg(ft: FTCfg) -> EFTAConfig:
    return EFTAConfig(mode=ft.mode, stride=ft.stride, block_kv=ft.block_kv,
                      unified=ft.unified, shadow_rowsum=ft.shadow_rowsum,
                      shadow_rowmax=ft.shadow_rowmax, unroll=ft.scan_unroll,
                      kv_stride_override=ft.kv_stride_override,
                      out_stride_override=ft.out_stride_override)


def attn_init(key, d_model: int, a: AttnCfg, dtype, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, a.num_heads * a.head_dim, dtype),
        "wk": dense_init(ks[1], d_model, a.num_kv_heads * a.head_dim, dtype),
        "wv": dense_init(ks[2], d_model, a.num_kv_heads * a.head_dim, dtype),
        "wo": dense_init(ks[3], a.num_heads * a.head_dim, d_model, dtype),
    }
    return p


def init_cache(batch: int, a: AttnCfg, *, cache_len: int, dtype,
               cross_len: int = 0, d_model: int = 0) -> KVCache:
    shape = (batch, a.num_kv_heads, cache_len, a.head_dim)
    cshape = (batch, a.num_kv_heads, max(cross_len, 1), a.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32),
        ck=jnp.zeros(cshape, dtype), cv=jnp.zeros(cshape, dtype))


def _paged_chunk(q, k, v, cache: PagedKVCache, *, cfg: EFTAConfig, window,
                 sm_scale, fault, interpret: bool):
    """One unified batched multi-token step against the paged block pool.

    ``q``/``k``/``v``: this step's projected (+RoPE'd) (B, H|Hkv, C, hd)
    chunk tensors; request ``b`` feeds ``cache.q_len[b]`` valid rows at
    positions ``pos .. pos + q_len - 1`` (1 row = decode, more = chunked
    prefill / extend / block repair — one mixed batch, one program).
    Appends every valid row's K/V into its request's blocks (a chunk may
    straddle a block edge), regenerates the checksums of exactly the blocks
    the chunk touched, then dispatches the fused paged-attention kernel over
    the block tables — append-before-attend, exactly mirroring the gather
    path's in-step scatter, so each chunk row attends to itself and its
    predecessors.

    Verification split: the kernel verifies every streamed block in its KV
    loop, but the append below refreshes the touched blocks' checksums from
    current content — doing that over a corrupted row would launder the
    corruption into a consistent (permanently silent) state. Only the
    *first* touched block can hold prior valid rows (``pos % bs > 0``;
    later touched blocks are written from row 0), so it is verified here
    against its pre-append checksums first, and its flag joins the kernel's
    ``bad`` plane. ``fault`` is the fused kernel's int32[8] descriptor (see
    ``repro.kernels.efta_paged``), not a FaultSpec.
    """
    bs = cache.k.shape[2]
    cs = cache.kc1.shape[2]
    thr = cks.kv_block_threshold(cache.k.dtype)
    bt, pos, q_len = cache.bt, cache.pos, cache.q_len
    mb = bt.shape[1]
    c_width = k.shape[2]
    j0 = pos // bs                                             # (B,)
    off = pos % bs

    # -- laundering guard: pre-verify the first touched block's prior rows
    tgt0 = jnp.take_along_axis(bt, j0[:, None], axis=1)[:, 0]
    bad_tk, _ = cks.verify_block(
        cache.k[tgt0], cks.Checksums(cache.kc1[tgt0], cache.kc2[tgt0]), cs,
        threshold=thr)
    bad_tv, _ = cks.verify_block(
        cache.v[tgt0], cks.Checksums(cache.vc1[tgt0], cache.vc2[tgt0]), cs,
        threshold=thr)
    tail_bad = (jnp.any(bad_tk | bad_tv, axis=-1) & (tgt0 > 0)
                & (off > 0) & (q_len > 0))                     # (B,)

    # -- scatter the chunk's K/V rows into their blocks (append-before-
    # attend); padding rows (c >= q_len) divert to the null scratch block
    c_idx = jnp.arange(c_width, dtype=jnp.int32)
    p_abs = pos[:, None] + c_idx[None, :]                      # (B, C)
    valid = c_idx[None, :] < q_len[:, None]
    jrow = jnp.clip(p_abs // bs, 0, mb - 1)
    tgt_rows = jnp.where(valid, jnp.take_along_axis(bt, jrow, axis=1), 0)
    offs = jnp.where(valid, p_abs % bs, 0)
    row_k = k.transpose(0, 2, 1, 3).astype(cache.k.dtype)      # (B,C,Hkv,hd)
    row_v = v.transpose(0, 2, 1, 3).astype(cache.v.dtype)
    new_k = cache.k.at[tgt_rows, :, offs, :].set(row_k)
    new_v = cache.v.at[tgt_rows, :, offs, :].set(row_v)

    # -- checksum generation for exactly the blocks the chunk touched (the
    # first may be partial, the rest start at row 0; untouched -> null)
    nt = (c_width + bs - 2) // bs + 1      # max blocks a C-row chunk spans
    jt = j0[:, None] + jnp.arange(nt, dtype=jnp.int32)[None, :]    # (B, nt)
    last = (pos + jnp.maximum(q_len, 1) - 1) // bs
    touched = (jt <= last[:, None]) & (q_len[:, None] > 0)
    tid = jnp.where(
        touched, jnp.take_along_axis(bt, jnp.clip(jt, 0, mb - 1), axis=1), 0)
    kc = cks.encode_kv(new_k[tid], cs)                 # (B, nt, Hkv, cs, hd)
    vc = cks.encode_kv(new_v[tid], cs)
    kc1 = cache.kc1.at[tid].set(kc.c1)
    kc2 = cache.kc2.at[tid].set(kc.c2)
    vc1 = cache.vc1.at[tid].set(vc.c1)
    vc2 = cache.vc2.at[tid].set(vc.c2)

    rep = efta_paged_attention_pallas(
        q, new_k, new_v,
        cks.Checksums(kc1, kc2), cks.Checksums(vc1, vc2),
        bt, pos + q_len, q_len, cfg=cfg, check_threshold=thr, window=window,
        sm_scale=sm_scale, fault=fault, interpret=interpret)

    tail_plane = (jnp.arange(mb, dtype=jnp.int32)[None, :] == j0[:, None]
                  ) & tail_bad[:, None]
    new_bad = jnp.maximum(cache.bad,
                          jnp.maximum(rep.bad_blocks, tail_plane)
                          .astype(jnp.int32))
    det = rep.detected[:, :5]
    report = FTReport(
        detected=det,
        corrected=det if cfg.mode == "correct" else det * 0,
        max_delta=jnp.zeros((3,), jnp.float32))
    new_cache = cache._replace(k=new_k, v=new_v, kc1=kc1, kc2=kc2,
                               vc1=vc1, vc2=vc2, pos=pos + q_len,
                               bad=new_bad)
    return rep.out, report, new_cache


def paged_rollback(k, v, kc1, kc2, vc1, vc2, bt, keep_pos, old_pos, *,
                   check_stride: int, threshold: float, max_span: int):
    """Fault-tolerant KV rollback: truncate rejected speculative rows.

    The propose→score→accept step appends every scored chunk row's K/V into
    the paged block pool *before* the acceptance verdict exists (append-
    before-attend). When the target rejects a draft suffix, rows
    ``keep_pos[b] .. old_pos[b] - 1`` of request ``b`` are junk that must not
    survive: this zeroes them (``kv_len`` truncation — matching the
    zero-padded-partial-block convention of the scatter path, so pool state
    is deterministic) and *re-generates* the touched tail blocks' checksums
    over the truncated content.

    Laundering guard: re-stamping a checksum from current content over a
    block that was corrupted between the scoring step's verify and this
    rollback would make the corruption permanently undetectable. So every
    touched block is first re-verified against its **pre-rollback**
    checksums; the returned ``bad`` plane (B, table_len) flags mismatches
    and the engine must re-prefill those blocks (the restamped checksums are
    then overwritten by the repair) — detection is never lost to a rollback.

    ``k``/``v``: (L, num_blocks+1, Hkv, bs, hd) pool arrays (row 0 = null
    block); ``kc1..vc2`` their resident checksum planes; ``bt`` (B, mb)
    block tables; ``keep_pos``/``old_pos`` (B,) with ``keep_pos <= old_pos``
    and ``old_pos - keep_pos <= max_span`` (the chunk width — static, so one
    compiled program serves every acceptance outcome). Slots with
    ``keep_pos == old_pos`` are untouched. Touched blocks are private tail
    blocks (shared blocks were COW-split before the speculative append), so
    no two slots roll back the same block.

    Returns ``(k, v, kc1, kc2, vc1, vc2, bad)``.
    """
    bs = k.shape[3]
    mb = bt.shape[1]
    cs = kc1.shape[3]
    nt = (max_span + bs - 2) // bs + 1     # max blocks a rollback can touch
    j0 = keep_pos // bs
    jt = j0[:, None] + jnp.arange(nt, dtype=jnp.int32)[None, :]    # (B, nt)
    last = (jnp.maximum(old_pos, keep_pos + 1) - 1) // bs
    touched = (jt <= last[:, None]) & (old_pos > keep_pos)[:, None]
    tid = jnp.where(
        touched, jnp.take_along_axis(bt, jnp.clip(jt, 0, mb - 1), axis=1), 0)

    # -- laundering guard: verify against the PRE-rollback checksums first
    bad_k, _ = cks.verify_block(
        k[:, tid], cks.Checksums(kc1[:, tid], kc2[:, tid]), cs,
        threshold=threshold)
    bad_v, _ = cks.verify_block(
        v[:, tid], cks.Checksums(vc1[:, tid], vc2[:, tid]), cs,
        threshold=threshold)
    bad_t = jnp.any(bad_k | bad_v, axis=(0, -1)) & (tid > 0)       # (B, nt)
    b_idx = jnp.arange(bt.shape[0])[:, None]
    bad = jnp.zeros(bt.shape, jnp.int32).at[
        b_idx, jnp.clip(jt, 0, mb - 1)].max(bad_t.astype(jnp.int32))

    # -- truncate: zero exactly the rejected rows of the touched blocks
    rows_abs = jt[:, :, None] * bs + jnp.arange(bs,
                                                dtype=jnp.int32)[None, None, :]
    kill = ((rows_abs >= keep_pos[:, None, None])
            & (rows_abs < old_pos[:, None, None])
            & touched[:, :, None])                                 # (B, nt, bs)
    kmask = kill[None, :, :, None, :, None]
    kb = jnp.where(kmask, 0.0, k[:, tid]).astype(k.dtype)
    vb = jnp.where(kmask, 0.0, v[:, tid]).astype(v.dtype)
    new_k = k.at[:, tid].set(kb)
    new_v = v.at[:, tid].set(vb)

    # -- re-stamp the touched blocks' checksums over the truncated content
    ck = cks.encode_kv(kb, check_stride)
    cv = cks.encode_kv(vb, check_stride)
    return (new_k, new_v,
            kc1.at[:, tid].set(ck.c1), kc2.at[:, tid].set(ck.c2),
            vc1.at[:, tid].set(cv.c1), vc2.at[:, tid].set(cv.c2), bad)


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attn_apply(
    params,
    x: jax.Array,                    # (B, S, d_model)
    *,
    acfg: AttnCfg,
    ft: FTCfg,
    window: Optional[int] = None,    # None = global (full) attention
    positions: Optional[jax.Array] = None,  # (S,) absolute positions
    cache: Optional[KVCache] = None,
    mode: str = "train",             # "train" | "prefill" | "decode"
    kv_x: Optional[jax.Array] = None,   # cross-attention memory (B, M, d)
    cross: bool = False,
    fault=None,
    mesh=None,
    interpret: bool = True,
) -> tuple[jax.Array, FTReport, Optional[KVCache]]:
    b, s, _ = x.shape
    hd, h, hkv = acfg.head_dim, acfg.num_heads, acfg.num_kv_heads
    cfg = efta_cfg(ft)
    cross = cross or (kv_x is not None)
    # Tensor-parallel attention: shard heads over 'model'. GQA groups are
    # hostile to GSPMD propagation (reshape H -> (Hkv, G) is non-divisible),
    # so under TP we materialize repeated KV heads (Megatron practice when
    # TP > kv_heads) and shard all of q/k/v on the padded head dim.
    tp = (mesh is not None and "model" in mesh.shape
          and mesh.shape["model"] > 1)

    def _tp_heads(t):
        if not tp:
            return t
        from repro.models.transformer import DP_AXES  # avoid cycle at import
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        spec = jax.sharding.PartitionSpec(dp if dp else None, "model",
                                          None, None)
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(mesh, spec))

    def _expand_kv(t):
        if not tp or t.shape[1] == h:
            return t
        g = h // t.shape[1]
        t = jnp.broadcast_to(t[:, :, None], (t.shape[0], t.shape[1], g,
                                             t.shape[2], t.shape[3]))
        return t.reshape(t.shape[0], h, t.shape[3], t.shape[4])

    def _tp_kv(t):
        # Decode: q is tiny (Sq=1) but the KV cache is huge — shard the KV
        # *head* dim over 'model' (GSPMD pads kv_heads up to the axis size)
        # instead of materializing the 7x-expanded KV. q stays replicated
        # across 'model'; the grouped einsum runs against local kv heads.
        if not tp:
            return t
        from repro.models.transformer import DP_AXES
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        spec = jax.sharding.PartitionSpec(dp if dp else None, "model",
                                          None, None)
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(mesh, spec))

    if positions is None:
        base = cache.pos if (cache is not None and mode == "decode") else 0
        positions = base + jnp.arange(s, dtype=jnp.int32)

    q = _tp_heads(_split_heads(matmul(x, params["wq"], ff_abft=ft.ff_abft),
                               h, hd))
    if cross:
        if cache is not None and mode == "decode":
            k, v = cache.ck, cache.cv
        else:
            k = _split_heads(matmul(kv_x, params["wk"], ff_abft=ft.ff_abft), hkv, hd)
            v = _split_heads(matmul(kv_x, params["wv"], ff_abft=ft.ff_abft), hkv, hd)
        if acfg.pos == "rope":
            q = rope(q.transpose(0, 2, 1, 3), positions,
                     acfg.rope_theta).transpose(0, 2, 1, 3)
        out, rep = attention_op(
            q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
            impl=ft.attn_impl, cfg=cfg, causal=False,
            sm_scale=acfg.softmax_scale, fault=fault, interpret=interpret)
        new_cache = None
        if cache is not None and mode == "prefill":
            new_cache = cache._replace(ck=k, cv=v)
        y = matmul(_merge_heads(out), params["wo"], ff_abft=ft.ff_abft)
        return y, rep, new_cache

    k = _split_heads(matmul(x, params["wk"], ff_abft=ft.ff_abft), hkv, hd)
    v = _split_heads(matmul(x, params["wv"], ff_abft=ft.ff_abft), hkv, hd)
    if acfg.pos == "rope":
        q = rope(q.transpose(0, 2, 1, 3), positions,
                 acfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k.transpose(0, 2, 1, 3), positions,
                 acfg.rope_theta).transpose(0, 2, 1, 3)

    if isinstance(cache, PagedKVCache):
        # Fused paged backend: unified natively batched ragged step straight
        # off the block tables (``positions`` is (B, S) here — per-request;
        # S is the chunk width, with ``cache.q_len`` valid rows per slot).
        if mode != "decode":
            raise NotImplementedError(
                "PagedKVCache attention is the unified batched decode/"
                "extend step; training prefill has no paged cache")
        out, rep, new_cache = _paged_chunk(
            q, k, v, cache, cfg=cfg, window=window,
            sm_scale=acfg.softmax_scale, fault=fault, interpret=interpret)
        y = matmul(_merge_heads(out), params["wo"], ff_abft=ft.ff_abft)
        return y, rep, new_cache

    new_cache = None
    if cache is None:
        # Training / encoding: self-attention over the full sequence.
        out, rep = attention_op(
            q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
            impl=ft.attn_impl, cfg=cfg, causal=acfg.causal,
            window=window, sm_scale=acfg.softmax_scale, fault=fault,
            interpret=interpret)
    else:
        cache_len = cache.k.shape[2]
        slots = positions % cache_len
        ck = cache.k.at[:, :, slots, :].set(k.astype(cache.k.dtype))
        cv = cache.v.at[:, :, slots, :].set(v.astype(cache.v.dtype))
        new_pos = positions[-1] + 1
        new_cache = cache._replace(k=ck, v=cv, pos=new_pos)
        if mode == "prefill":
            # Attend within the prompt itself (fresh cache).
            out, rep = attention_op(
                q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
                impl=ft.attn_impl, cfg=cfg, causal=acfg.causal,
                window=window, sm_scale=acfg.softmax_scale, fault=fault,
                interpret=interpret)
        else:
            # Decode: attend over the valid region of the (ring) cache.
            # Each slot's absolute position is reconstructed so causal and
            # sliding-window masks apply exactly even after wraparound.
            slot_idx = jnp.arange(cache_len, dtype=jnp.int32)
            last_written = new_pos - 1 - ((new_pos - 1 - slot_idx) % cache_len)
            kv_positions = jnp.where(last_written >= 0, last_written, -1)
            out, rep = attention_op(
                q, _tp_kv(ck), _tp_kv(cv),
                impl="efta" if ft.attn_impl == "efta_pallas"
                else ft.attn_impl,
                cfg=cfg, causal=True, window=window,
                q_offset=positions[0], kv_positions=kv_positions,
                sm_scale=acfg.softmax_scale, fault=fault, interpret=interpret)
    y = matmul(_merge_heads(out), params["wo"], ff_abft=ft.ff_abft)
    return y, rep, new_cache
