"""Paper Fig. 13: selective neuron value restriction vs DMR for softmax
protection inside the fused attention."""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, qkv, time_fn
from repro.core import EFTAConfig
from repro.core.decoupled import dmr_row_softmax
from repro.core.efta import efta_attention

B, H, S, D = 4, 4, 512, 64


def run():
    q, k, v = qkv(B, H, H, S, D, jnp.float32)
    base_cfg = EFTAConfig(mode="off", block_kv=128)
    snvr_cfg = EFTAConfig(mode="detect", stride=16, block_kv=128)
    base = time_fn(jax.jit(functools.partial(efta_attention, cfg=base_cfg)),
                   q, k, v)
    snvr = time_fn(jax.jit(functools.partial(efta_attention, cfg=snvr_cfg)),
                   q, k, v)
    # DMR on softmax: redundant softmax execution over the full scores.
    # CPU wall-time cannot resolve the duplicate exp (cache-resident), so the
    # structural cost is reported from compiled HLO FLOPs (deterministic).
    s_full = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (D ** 0.5)
    f_dmr = jax.jit(lambda s: dmr_row_softmax(s)[0])
    f_soft = jax.jit(lambda s: jax.nn.softmax(s, -1))
    t_dmr = time_fn(f_dmr, s_full)
    t_soft = time_fn(f_soft, s_full)
    fl_dmr = f_dmr.lower(s_full).compile().cost_analysis().get("flops", 0)
    fl_soft = f_soft.lower(s_full).compile().cost_analysis().get("flops", 1)
    rows = [
        {"name": "efta_snvr", "us": snvr * 1e6,
         "derived": f"softmax_protect_oh={(snvr-base)/base*100:.1f}%"},
        {"name": "dmr_softmax", "us": t_dmr * 1e6,
         "derived": (f"wall_oh={(t_dmr-t_soft)/t_soft*100:.1f}%"
                     f";hlo_flops_oh={(fl_dmr-fl_soft)/fl_soft*100:.0f}%")},
        {"name": "plain_softmax", "us": t_soft * 1e6, "derived": "baseline"},
    ]
    emit(rows, "Fig13: SNVR vs DMR softmax protection")
    return rows


if __name__ == "__main__":
    run()
