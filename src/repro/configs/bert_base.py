"""bert-base (paper Table 3): 12L 12H head_dim=64 encoder-only."""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="bert-base", family="encoder",
    num_layers=12, d_model=768, d_ff=3072, vocab_size=30522,
    attn=AttnCfg(num_heads=12, num_kv_heads=12, head_dim=64, pos="learned",
                 causal=False),
    norm="layernorm", glu=False, act="gelu", max_seq=512,
    source="paper Table 3",
)
