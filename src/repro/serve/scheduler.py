"""Continuous-batching request scheduler (FCFS, iteration-level).

Orca-style iteration scheduling: at *every* decode step the scheduler first
evicts finished requests (EOS or token budget), then admits waiting requests
into freed cache slots. Admission and eviction are host-side decisions made
between jitted decode steps; the decode computation itself always runs at the
full fixed slot count with finished/empty slots masked out.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.sampling import SamplingParams


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its lifetime bookkeeping."""

    rid: int
    prompt: np.ndarray                    # (T,) int32
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    # number of engine decode-step retries this request sat through
    retries: int = 0
    # paged engine: pool block ids backing this request's KV, table order
    block_ids: List[int] = dataclasses.field(default_factory=list)
    # paged engine: leading block_ids that came from the prefix cache
    n_prefix_hit: int = 0
    # paged engine: monotone admission sequence (preemption picks the
    # youngest victim; -1 = never admitted)
    admit_order: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def num_generated(self) -> int:
        return len(self.generated)

    def is_done(self) -> bool:
        if self.num_generated >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.generated
                and self.generated[-1] == self.eos_id)


@dataclasses.dataclass
class ScheduleDecision:
    admitted: List[Request]
    evicted: List[Request]


class ContinuousBatchingScheduler:
    """FCFS admission over a fixed slot budget.

    ``chunk_budget`` caps the *prompt* tokens the unified chunked step may
    process per iteration (None = unbounded): the paged engine's mixed
    batches interleave prefill chunks with decodes, and without a budget a
    long prompt monopolizes the step and head-of-line-blocks every decoding
    request's next token. See :meth:`plan_chunks`.
    """

    def __init__(self, n_slots: int, chunk_budget: Optional[int] = None):
        self.n_slots = n_slots
        self.chunk_budget = chunk_budget
        self.waiting: Deque[Request] = collections.deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self.finished: List[Request] = []

    def add(self, req: Request) -> None:
        if req.state is not RequestState.WAITING:
            raise ValueError(f"request {req.rid} already scheduled")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def step(self, try_admit, release) -> ScheduleDecision:
        """One scheduling iteration.

        ``try_admit(req) -> Optional[slot]`` attempts to reserve every
        resource the request needs (cache slot, and for the paged engine its
        KV blocks); None means the request cannot run *yet*. A failed
        admission leaves the request at the **head** of the queue and stops
        admitting — FCFS means head-of-line blocking, never queue-jumping: a
        request that repeatedly fails allocation keeps its position, and a
        smaller request behind it must wait its turn. ``release(req)`` frees
        a finished request's resources (called while ``req.slot`` is still
        set).
        """
        evicted: List[Request] = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.is_done():
                req.state = RequestState.FINISHED
                del self.running[slot]
                release(req)
                req.slot = None
                self.finished.append(req)
                evicted.append(req)

        admitted: List[Request] = []
        while self.waiting:
            req = self.waiting[0]
            slot = try_admit(req)
            if slot is None:
                break       # head keeps its FCFS position for the next step
            self.waiting.popleft()
            req.slot = slot
            req.state = RequestState.RUNNING
            self.running[slot] = req
            admitted.append(req)
        return ScheduleDecision(admitted=admitted, evicted=evicted)

    def preempt(self, req: Request) -> None:
        """Push a running request back to the *front* of the waiting queue
        (pool pressure). Its resources are the caller's to release; it keeps
        its generated tokens and resumes from them on re-admission, and it is
        first in line — preemption must not cost a request its FCFS turn."""
        if req.state is not RequestState.RUNNING:
            raise ValueError(f"request {req.rid} is not running")
        del self.running[req.slot]
        req.state = RequestState.WAITING
        self.waiting.appendleft(req)

    def active_rows(self) -> Sequence[Request]:
        return [self.running[s] for s in sorted(self.running)]

    def plan_chunks(self, demands: Sequence[tuple],
                    chunk_size: int,
                    draft_wants: Optional[Dict[int, int]] = None):
        """Split one unified step's token budget across the active requests.

        ``demands``: ``(request, n_remaining)`` pairs — how many feed tokens
        (prompt suffix + the pending decode token) each active request still
        owes. Returns ``rid -> tokens granted this step``.

        Fairness contract: every request with work is granted its first
        token unconditionally — a decoding request's next token is never
        starved by prefill traffic. Only the *surplus* (prompt chunk rows
        beyond the first, up to ``chunk_size`` per request) draws from
        ``chunk_budget``, handed out FCFS by admission order so an early
        long prompt still finishes before a later one accelerates.

        ``draft_wants`` (rid -> K) adds the speculative-decoding demand:
        how many *draft* rows each steady-state request would like to score
        this step. Draft rows ride the SAME ``chunk_budget`` as prompt
        surplus but rank strictly *after* it (prompt chunks are what queued
        admissions are waiting on — speculation must never starve decodes
        or admissions, only spend leftover budget), FCFS by admission order,
        capped at ``chunk_size - 1`` per slot (the scored chunk is the
        pending token plus the drafts). When given, returns
        ``(grants, draft_grants)``.
        """
        grants = {req.rid: min(1, rem) for req, rem in demands}
        budget = self.chunk_budget
        for req, rem in sorted(demands, key=lambda d: d[0].admit_order):
            extra = min(rem, chunk_size) - grants[req.rid]
            if extra <= 0:
                continue
            if budget is not None:
                extra = min(extra, budget)
                budget -= extra
            grants[req.rid] += extra
        if draft_wants is None:
            return grants
        draft_grants: Dict[int, int] = {}
        for req, rem in sorted(demands, key=lambda d: d[0].admit_order):
            want = min(draft_wants.get(req.rid, 0),
                       chunk_size - grants[req.rid])
            if want <= 0 or rem > 1:
                draft_grants[req.rid] = 0
                continue       # drafts extend steady-state decodes only
            if budget is not None:
                want = min(want, budget)
                budget -= want
            draft_grants[req.rid] = want
        return grants, draft_grants
