"""Fault-tolerant checkpointing: per-leaf numpy blobs + msgpack manifest.

Design (1000-node posture):
  * every leaf is stored as a standalone ``.npy`` under a content-addressed
    name, with a manifest mapping pytree paths -> files + shapes + dtypes.
    At scale each host writes only its shards; here (single host) the full
    array is written — the interface is shard-ready (``shard_index``).
  * RESTORE RESHARDS: arrays are loaded as host numpy and re-placed with
    ``jax.device_put`` under the *current* mesh's shardings, so a checkpoint
    taken on 16x16 restores onto 2x16x16 or a degraded 15x16 replacement
    mesh (elastic restart).
  * async snapshots: ``save_async`` hands the host copy to a worker thread —
    the train loop keeps stepping while the previous snapshot flushes.
  * atomicity: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash
    mid-save never corrupts the latest-good checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

MANIFEST = "manifest.json"

# numpy .npy can't serialize ml_dtypes (bfloat16, fp8) natively: store the
# raw bits under a same-width integer view and record the logical dtype.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _BITCAST:
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def save(ckpt_dir: str | Path, tree: Any, *, step: int,
         extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf))
        storable, dtype_name = _to_storable(arr)
        np.save(tmp / name, storable)
        manifest["leaves"][_path_str(path)] = {
            "file": name, "shape": list(arr.shape), "dtype": dtype_name}
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    if ckpt_dir.exists():
        shutil.rmtree(ckpt_dir)
    os.replace(tmp, ckpt_dir)
    return ckpt_dir


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, ckpt_dir, tree, *, step, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(ckpt_dir, host_tree, step=step, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def restore(ckpt_dir: str | Path, target: Any, *, mesh=None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Load a checkpoint into ``target``'s structure, resharding onto the
    current mesh. Returns (tree, step, extra)."""
    ckpt_dir = Path(ckpt_dir)
    manifest = json.loads((ckpt_dir / MANIFEST).read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = _path_str(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        info = manifest["leaves"][key]
        arr = _from_storable(np.load(ckpt_dir / info["file"]), info["dtype"])
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype
                                            if hasattr(leaf, "dtype") else None))
    tree = treedef.unflatten(leaves)
    return tree, int(manifest["step"]), manifest.get("extra", {})


def latest_step(root: str | Path) -> Optional[Path]:
    root = Path(root)
    if not root.exists():
        return None
    cands = [p for p in root.iterdir()
             if p.is_dir() and (p / MANIFEST).exists()]
    if not cands:
        return None
    return max(cands, key=lambda p: json.loads(
        (p / MANIFEST).read_text())["step"])
