"""Serving launcher: fault-tolerant continuous batching over a KV-slot pool
or (``--paged``) the checksummed paged block pool with prefix caching.

CPU-scale demos:
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-smoke \
      --requests 8 --slots 4 --max-prompt 24 --gen 16 --inject-faults 3
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-smoke --paged \
      --shared-prefix 16 --kv-flips 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import FaultSpec, Site
from repro.models import build_model
from repro.serve import (PagedServeEngine, SamplingParams, ServeEngine,
                         batch_faults)
from repro.utils import get_logger


def _static_batch_serve(cfg, model, params, rng, args, log):
    """Fallback for families the engine does not batch continuously yet
    (vlm/audio frontends, ssm, enc-dec): the seed's static-batch loop."""
    from repro.serve import greedy_generate
    import jax.numpy as jnp

    tokens = jnp.asarray(rng.integers(
        0, cfg.vocab_size, size=(args.requests, args.max_prompt)), jnp.int32)
    kw = {}
    if cfg.family in ("vlm", "audio"):
        kw["frontend"] = jnp.asarray(rng.standard_normal(
            (args.requests, cfg.frontend_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.family == "encdec":
        kw["enc_tokens"] = jnp.ones((args.requests, 8), jnp.int32)
    t0 = time.time()
    out, rep = greedy_generate(model, params, tokens, steps=args.gen, **kw)
    dt = time.time() - t0
    log.info("static-batch served %s tokens in %.2fs (%.1f tok/s); EFTA "
             "detected=%s", out.shape, dt, out.size / dt,
             np.asarray(rep.detected).tolist())
    print(np.asarray(out))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="KV slots per request (0 = model max_seq)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--inject-faults", type=int, default=0,
                    help="number of decode steps hit by a random SEU")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the checksummed paged KV block pool "
                         "(prefix caching + read-time corruption repair)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size (0 = ring-equivalent capacity)")
    ap.add_argument("--kernel", choices=("gather", "fused"), default=None,
                    help="paged decode backend: 'gather' materializes each "
                         "table as a contiguous view and verifies checksums "
                         "outside the kernel (portable baseline); 'fused' "
                         "consumes block tables directly in the paged EFTA "
                         "Pallas kernel with in-loop verification (interpret "
                         "mode off-TPU)")
    ap.add_argument("--kv-verify", choices=("always", "stamped"),
                    default="always",
                    help="gather-backend read-time verify policy: 'always' "
                         "folds every table block each step; 'stamped' skips "
                         "blocks untouched since their last verified read "
                         "(amortized checksums; detection of a flip in a "
                         "stamped block is deferred to its next write or "
                         "the next scrub pass)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="serve through the unified multi-token step "
                         "(implies --paged --kernel fused): every engine "
                         "iteration is one mixed batch in which new prompts "
                         "prefill a chunk while running requests decode — "
                         "one compiled program instead of one per prompt "
                         "bucket, and long prompts never head-of-line-block "
                         "decodes")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="chunk width of the unified multi-token step "
                         "(0 = 2 * block_size); also the gather backend's "
                         "fixed prefill/extend/repair chunk width")
    ap.add_argument("--chunk-budget", type=int, default=0,
                    help="max prompt tokens processed per mixed step "
                         "(0 = unbounded); decodes always proceed")
    ap.add_argument("--scrub-interval", type=int, default=0,
                    help="with --kv-verify stamped: re-fold the oldest-"
                         "verified live blocks every N committed steps "
                         "(bounds the stamped policy's deferred-detection "
                         "window; 0 = off)")
    ap.add_argument("--speculate", choices=("off", "ngram", "draft"),
                    default="off",
                    help="speculative decoding through the propose→score→"
                         "accept step (implies --paged): 'ngram' self-drafts "
                         "by prompt lookup, 'draft' decodes a small draft "
                         "model (--draft-model) through the same EFTA path; "
                         "the unified chunk scores all K drafts in one "
                         "protected launch and rejected rows roll back with "
                         "checksum-verified truncation")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="max draft tokens proposed per request per step")
    ap.add_argument("--draft-model", default="",
                    help="arch name of the draft model for --speculate "
                         "draft (defaults to the serving arch — pure "
                         "self-drafting, acceptance ~1)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every request "
                         "(exercises the prefix cache)")
    ap.add_argument("--kv-flips", type=int, default=0,
                    help="random resident KV-block bit flips injected "
                         "between decode steps (paged only)")
    ap.add_argument("--ft-mode", default=None,
                    help="override the config's EFTA mode (off/detect/correct)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    log = get_logger("serve")
    if args.chunked_prefill:
        if args.kernel == "gather":
            ap.error("--chunked-prefill is the fused unified step; it "
                     "contradicts --kernel gather (the gather backend "
                     "chunks its prefill at admission instead)")
        args.paged = True
        args.kernel = "fused"
    if args.speculate != "off":
        args.paged = True              # the propose→score→accept step is
        #                                the paged engine's unified contract
    if not args.paged and (args.kernel is not None
                           or args.kv_verify != "always"
                           or args.chunk_size or args.chunk_budget
                           or args.scrub_interval):
        ap.error("--kernel/--kv-verify/--chunk-size/--chunk-budget/"
                 "--scrub-interval configure the paged engine; add --paged")
    args.kernel = args.kernel or "gather"

    cfg = get_config(args.arch)
    if args.ft_mode:
        cfg = dataclasses.replace(
            cfg, ft=dataclasses.replace(cfg.ft, mode=args.ft_mode))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    if cfg.family not in ("dense", "moe"):
        # frontend / ssm / encdec families predate the continuous-batching
        # engine: serve them through the static-batch greedy loop
        _static_batch_serve(cfg, model, params, rng, args, log)
        return

    if args.paged:
        draft_model = draft_params = None
        if args.speculate == "draft":
            dcfg = get_config(args.draft_model or args.arch)
            if args.ft_mode:
                dcfg = dataclasses.replace(
                    dcfg, ft=dataclasses.replace(dcfg.ft, mode=args.ft_mode))
            draft_model = build_model(dcfg)
            if not args.draft_model or args.draft_model == args.arch:
                draft_params = params      # self-drafting: share weights
            else:
                draft_params = draft_model.init(
                    jax.random.PRNGKey(args.seed + 1))
        eng = PagedServeEngine(model, params, n_slots=args.slots,
                               cache_len=args.cache_len or None,
                               block_size=args.block_size,
                               num_blocks=args.num_blocks or None,
                               kernel=args.kernel, kv_verify=args.kv_verify,
                               chunk_size=args.chunk_size or None,
                               chunk_budget=args.chunk_budget or None,
                               scrub_interval=args.scrub_interval,
                               speculate=args.speculate,
                               draft_len=args.draft_len,
                               draft_model=draft_model,
                               draft_params=draft_params)
    else:
        eng = ServeEngine(model, params, n_slots=args.slots,
                          cache_len=args.cache_len or None)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)
    shared = rng.integers(0, cfg.vocab_size,
                          (args.shared_prefix,)).astype(np.int32)
    for _ in range(args.requests):
        t = int(rng.integers(2, args.max_prompt + 1))
        prompt = np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)])
        eng.submit(prompt, max_new_tokens=args.gen, sampling=sampling)

    faults_by_step = {}
    n_faults = min(args.inject_faults, args.gen)
    # distinct steps so every requested SEU is actually injected
    for step in rng.choice(args.gen, size=n_faults, replace=False):
        slot = int(rng.integers(0, args.slots))
        spec = FaultSpec.single(
            Site(int(rng.choice([0, 2, 3, 4]))),
            block=0, batch=0, head=int(rng.integers(0, 4)),
            row=0, col=int(rng.integers(0, 16)),
            bit=int(rng.integers(22, 30)))
        faults_by_step[int(step)] = batch_faults(args.slots, {slot: spec})

    t0 = time.time()
    if args.paged and args.kv_flips:
        # drive manually so resident-state SEUs strike *between* steps
        outs, i, flips_left = {}, 0, args.kv_flips
        while eng.scheduler.has_work:
            live = [r for r in eng.scheduler.active_rows()
                    if not r.is_done() and eng._pos[r.slot] > 0]
            if live and flips_left and rng.integers(0, 2):
                req = live[int(rng.integers(0, len(live)))]
                j = int(rng.integers(0, len(req.block_ids)))
                eng.inject_kv_fault(
                    layer=int(rng.integers(0, cfg.num_layers)),
                    block=req.block_ids[j],
                    head=int(rng.integers(0, cfg.attn.num_kv_heads)),
                    row=int(rng.integers(0, args.block_size)),
                    col=int(rng.integers(0, cfg.attn.head_dim)),
                    bit=int(rng.integers(24, 31)),
                    into="k" if rng.integers(0, 2) else "v")
                flips_left -= 1
            eng.step(faults=faults_by_step.get(i))
            i += 1
        outs = {r.rid: np.asarray(r.generated, np.int32)
                for r in eng.scheduler.finished}
    else:
        outs = eng.run(faults_by_step)
    dt = time.time() - t0
    log.info("served %d requests (%d tokens) in %.2fs (%.1f tok/s) over "
             "%d slots in %d engine steps", len(outs), eng.stats.tokens, dt,
             eng.stats.tokens / dt, args.slots, eng.stats.steps)
    summ = eng.telemetry.summary()
    log.info("EFTA telemetry: detected=%d retries=%d status=%s",
             summ["detected"], summ["retries"], summ["status"])
    if args.paged:
        ps, xs = eng.paged_stats, eng.pool.prefix.stats
        log.info("paged cache: prefix hits=%d/%d tokens, kv detected=%d "
                 "repaired=%d scrubbed=%d preemptions=%d evictions=%d "
                 "chunked-prefill tokens=%d",
                 xs.hit_tokens, xs.lookup_tokens, ps.kv_detected_blocks,
                 ps.kv_repaired_blocks, ps.kv_scrubbed_blocks,
                 ps.preemptions, eng.pool.blocks.stats.evictions,
                 ps.chunked_prefill_tokens)
        if args.speculate != "off":
            log.info("speculation (%s): acceptance=%.2f (%d/%d drafts), "
                     "spec steps=%d, tokens/step=%.2f, rolled-back rows=%d, "
                     "rollback-guard detections=%d",
                     args.speculate, eng.acceptance_rate,
                     ps.spec_accepted_tokens, ps.spec_proposed_tokens,
                     ps.spec_steps,
                     eng.stats.tokens / max(eng.stats.steps, 1),
                     ps.spec_rolled_back_rows, ps.rollback_detected_blocks)
    for rid in sorted(outs):
        st = eng.telemetry.requests.get(rid)
        log.info("request %d: %d tokens, detected=%d corrected=%d retries=%d",
                 rid, len(outs[rid]), st.total_detected if st else 0,
                 st.total_corrected if st else 0, st.retries if st else 0)
    print({rid: outs[rid].tolist() for rid in sorted(outs)})


if __name__ == "__main__":
    main()
