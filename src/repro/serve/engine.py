"""Fault-tolerant continuous-batching serve engine.

Replaces the seed's per-token Python loop with a **fixed-shape jitted decode
step** over a slot pool: every step decodes one token for all ``n_slots``
cache slots at once (inactive slots compute garbage that is ignored), so XLA
compiles exactly two programs — one prefill per prompt-length bucket and one
batched decode — regardless of how requests arrive, finish, or interleave.

Per-slot independence (each request has its own position counter, ring cache
and causal mask) comes from vmapping the model's batch-1 decode over the slot
axis: the per-slot ``kv_positions`` ring reconstruction in
``repro.models.attention`` does the masking, and EFTA's fault tolerance rides
along unchanged. The vmapped computation is numerically the batch of
independent sequential decodes, which is what makes the engine token-identical
to ``greedy_generate`` run per request.

Fault handling (the paper's end-to-end story): EFTA's ``FTReport`` comes back
*per slot* from the vmapped decode. In ``mode="correct"`` with exact shadow
correction, detected SEUs are fixed in-kernel and only counted. Whenever a
step reports faults it could not exactly fix — ``mode="detect"``, or
SNVR-analytic rowsum approximation (``shadow_rowsum=False``) — the engine
**retries the step** from the pre-step cache state (SEUs are transient; the
re-execution is clean) and only then commits. Per-request detection /
correction / retry rates aggregate in ``ft_runtime.ServeFaultTelemetry``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fault import FaultSpec
from repro.ft_runtime.monitor import ServeFaultTelemetry
from repro.models.api import Model
from repro.serve.cache import KVCachePool, add_unit_batch, drop_unit_batch
from repro.serve.sampling import SamplingParams, request_key, sample_tokens
from repro.serve.scheduler import ContinuousBatchingScheduler, Request


def batch_faults(n_slots: int,
                 per_slot: Optional[Dict[int, FaultSpec]] = None) -> FaultSpec:
    """Stack per-slot fault specs into the (n_slots, n_faults) layout the
    vmapped decode expects. Slots without an entry get a disabled spec."""
    per_slot = per_slot or {}
    nf = max([s.site.shape[0] for s in per_slot.values()] or [1])
    rows = []
    for i in range(n_slots):
        spec = per_slot.get(i, FaultSpec.none(nf))
        if spec.site.shape[0] != nf:
            pad = FaultSpec.none(nf - spec.site.shape[0])
            spec = FaultSpec(*(jnp.concatenate([a, b])
                               for a, b in zip(spec, pad)))
        rows.append(spec)
    return FaultSpec(*(jnp.stack(col) for col in zip(*rows)))


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    retries: int = 0
    tokens: int = 0
    prefills: int = 0


class ServeEngine:
    """Continuous-batching engine over a fixed KV-slot pool.

    Decoder-only attention-cache families (dense / MoE). Prompts are padded
    to power-of-two buckets for prefill (bounded retraces); the decode loop
    is a single jitted computation at (n_slots,) shape.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 8,
                 cache_len: Optional[int] = None, max_retries: int = 2,
                 retry_on_detect: bool = True, min_prefill_bucket: int = 8):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"serve engine supports decoder-only attention families; "
                f"got {cfg.family!r}")
        self.model = model
        self.params = params
        self.cache_len = cache_len or cfg.max_seq
        self.n_slots = n_slots
        self.max_retries = max_retries
        self.retry_on_detect = retry_on_detect
        self.min_prefill_bucket = min_prefill_bucket
        # SNVR analytic rowsum fallback (paper Case 3) bounds the error but
        # is not exact — treat such "corrections" as retry-worthy.
        self._exact_rowsum = cfg.ft.shadow_rowsum
        self.pool = self._make_pool()
        self.scheduler = ContinuousBatchingScheduler(n_slots)
        self.telemetry = ServeFaultTelemetry()
        self.stats = EngineStats()
        self._rid = 0
        # per-slot host mirrors of the sampling state
        self._pending = np.zeros((n_slots,), np.int32)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._rids = np.zeros((n_slots,), np.int32)
        self._counters = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        self._no_faults = batch_faults(n_slots)  # reused every clean step

    def _make_pool(self):
        """Cache-pool factory; the paged engine overrides this."""
        return KVCachePool(self.model, self.n_slots, self.cache_len)

    def _try_admit(self, req: Request) -> Optional[int]:
        """Reserve resources for one admission; None = cannot run yet."""
        return self.pool.alloc()

    def _release_request(self, req: Request) -> None:
        self.pool.release(req.slot)

    # -- jitted computations ------------------------------------------------

    def _prefill_fn(self, params, tokens, row_cache, length, fault):
        return self.model.prefill(params, tokens, row_cache,
                                  lengths=length, fault=fault)

    def _decode_fn(self, params, tokens, state, faults, temps, topks,
                   seeds, rids, counters):
        axes = self.pool.vmap_axes()

        def one(tok, row, f):
            logits, rep, new_row = self.model.decode_step(
                params, tok[None, None], add_unit_batch(row), fault=f)
            return logits[0], rep, drop_unit_batch(new_row)

        logits, rep, new_state = jax.vmap(
            one, in_axes=(0, axes, 0), out_axes=(0, 0, axes))(
                tokens, state, faults)

        def key_of(seed, rid, counter):
            return jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)

        keys = jax.vmap(key_of)(seeds, rids, counters)
        next_tokens = sample_tokens(logits, temperature=temps, top_k=topks,
                                    keys=keys)
        return next_tokens, rep, new_state

    # -- request lifecycle --------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size > self.cache_len:
            raise ValueError(f"prompt of {prompt.size} tokens exceeds the "
                             f"{self.cache_len}-slot KV cache")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.cache_len:
            # a ring wrap would silently drop the earliest KV entries and
            # break the token-identical-to-sequential guarantee
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds cache_len ({self.cache_len}); raise cache_len")
        rid = self._rid
        self._rid += 1
        self.scheduler.add(Request(rid=rid, prompt=prompt,
                                   max_new_tokens=max_new_tokens,
                                   sampling=sampling or SamplingParams(),
                                   eos_id=eos_id))
        return rid

    def _bucket(self, n: int) -> int:
        b = self.min_prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.cache_len)

    def _admit(self, req: Request) -> None:
        t = req.prompt_len
        lp = max(self._bucket(t), t)
        padded = np.zeros((1, lp), np.int32)
        padded[0, :t] = req.prompt
        row = self.model.init_cache(1, cache_len=self.cache_len)
        length = jnp.asarray([t], jnp.int32)
        fault = FaultSpec.none(1)
        logits, rep, new_row = self._prefill(
            self.params, jnp.asarray(padded), row, length, fault)
        det_acc = np.asarray(rep.detected, np.int64).reshape(-1)[:5].copy()
        cor_acc = np.asarray(rep.corrected, np.int64).reshape(-1)[:5].copy()
        retries = 0
        while self._needs_retry_rows(rep, rows=None) and \
                retries < self.max_retries:
            retries += 1
            logits, rep, new_row = self._prefill(
                self.params, jnp.asarray(padded), row, length, fault)
            det_acc += np.asarray(rep.detected).reshape(-1)[:5]
            cor_acc += np.asarray(rep.corrected).reshape(-1)[:5]
        self.telemetry.observe_prefill(req.rid, det_acc, cor_acc,
                                       retries=retries)
        req.retries += retries
        self.stats.prefills += 1
        self.stats.retries += retries

        slot = req.slot
        self.pool.write_row(slot, new_row, t)
        s = req.sampling
        key = jax.random.fold_in(request_key(s, req.rid), 0)
        first = sample_tokens(
            logits.astype(jnp.float32),
            temperature=jnp.asarray([s.temperature], jnp.float32),
            top_k=jnp.asarray([s.top_k], jnp.int32), keys=key[None])
        tok = int(first[0])
        req.generated.append(tok)
        self._pending[slot] = tok
        self._temps[slot] = s.temperature
        self._topks[slot] = s.top_k
        self._seeds[slot] = s.seed
        self._rids[slot] = req.rid
        self._counters[slot] = 1
        self.stats.tokens += 1

    # -- stepping -----------------------------------------------------------

    def _needs_retry_rows(self, rep, rows: Optional[Sequence[int]]) -> bool:
        if not self.retry_on_detect:
            return False
        det = np.asarray(rep.detected).reshape(-1, 5) \
            if np.asarray(rep.detected).ndim > 1 \
            else np.asarray(rep.detected).reshape(1, 5)
        cor = np.asarray(rep.corrected).reshape(det.shape)
        uncorrected = det.sum(-1) - cor.sum(-1)
        approx = np.zeros_like(uncorrected) if self._exact_rowsum \
            else cor[:, 3]
        need = (uncorrected > 0) | (approx > 0)
        if rows is not None:
            need = need[list(rows)]
        return bool(need.any())

    def step(self, faults: Optional[FaultSpec] = None) -> List[Request]:
        """One engine iteration: schedule, (re)decode, commit. Returns the
        requests that finished during this iteration. ``faults`` is an
        optional (n_slots, n_faults) SEU batch injected into this step's
        first decode attempt (retries re-execute clean)."""
        decision = self.scheduler.step(self._try_admit, self._release_request)
        for req in decision.admitted:
            self._admit(req)
        finished = list(decision.evicted)
        active = [r.slot for r in self.scheduler.active_rows()]
        if not active:
            return finished

        if faults is None:
            faults = self._no_faults
        args = (jnp.asarray(self._pending), self.pool.state, faults,
                jnp.asarray(self._temps), jnp.asarray(self._topks),
                jnp.asarray(self._seeds), jnp.asarray(self._rids),
                jnp.asarray(self._counters))
        next_tokens, rep, new_state = self._decode(self.params, *args)
        det_acc = np.asarray(rep.detected, np.int64).copy()
        cor_acc = np.asarray(rep.corrected, np.int64).copy()
        retries = 0
        while self._needs_retry_rows(rep, rows=active) and \
                retries < self.max_retries:
            retries += 1
            next_tokens, rep, new_state = self._decode(
                self.params, args[0], args[1], self._no_faults, *args[3:])
            det_acc += np.asarray(rep.detected)
            cor_acc += np.asarray(rep.corrected)

        # commit
        self.pool.state = new_state
        next_np = np.asarray(next_tokens)
        per_request = {}
        for req in self.scheduler.active_rows():
            if req.is_done():
                continue  # finished at admission; evicted next iteration
            slot = req.slot
            tok = int(next_np[slot])
            req.generated.append(tok)
            req.retries += retries
            self._pending[slot] = tok
            self._counters[slot] += 1
            per_request[req.rid] = (det_acc[slot], cor_acc[slot])
            self.stats.tokens += 1
        self.telemetry.observe_step(per_request, retries=retries)
        self.stats.steps += 1
        self.stats.retries += retries
        return finished

    def run(self, faults_by_step: Optional[Dict[int, FaultSpec]] = None
            ) -> Dict[int, np.ndarray]:
        """Drive until every submitted request finishes. ``faults_by_step``
        optionally injects a per-slot SEU batch at given step indices.
        Returns rid -> generated tokens."""
        faults_by_step = faults_by_step or {}
        i = 0
        while self.scheduler.has_work:
            self.step(faults=faults_by_step.get(i))
            i += 1
        return {r.rid: np.asarray(r.generated, np.int32)
                for r in self.scheduler.finished}
