"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads; sliding-window
attention except 3 full-attention layers (first/middle/last).
[arXiv:2411.13676; hf]"""
from repro.configs.base import AttnCfg, FTCfg, ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, d_ff=5504, vocab_size=32001,
    attn=AttnCfg(num_heads=25, num_kv_heads=5, head_dim=64,
                 sliding_window=1024),
    ssm=SSMCfg(kind="mamba", state_dim=16, expand=2),
    source="arXiv:2411.13676",
)
