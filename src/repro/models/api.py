"""Public model API: build, train-loss, and serving entry points."""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.efta import FTReport
from repro.models import ssm as ssm_lib
from repro.models.attention import init_cache as init_attn_cache
from repro.models.transformer import forward, init_params

Z_LOSS = 1e-4


def _last_logits(logits, lengths):
    """(B, S, V) -> (B, V) at each row's true last token. ``lengths`` (B,)
    supports ragged prompts padded to a common width (None = last column);
    causality guarantees the gathered logits ignore the padding."""
    if lengths is None:
        return logits[:, -1, :]
    idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0,
                   logits.shape[1] - 1)
    return jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]


class Model:
    """Thin, stateless handle: all methods are pure functions of params."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------------
    def init(self, rng) -> Any:
        return init_params(rng, self.cfg)

    # -- training -----------------------------------------------------------
    def loss(self, params, batch, *, mesh=None):
        logits, rep, aux, _ = forward(params, self.cfg, batch, mesh=mesh)
        targets = batch["targets"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        # Vocab-parallel cross-entropy: extract the target logit with a fused
        # iota-compare-select reduction instead of take_along_axis — a gather
        # along the sharded vocab axis would force GSPMD to all-gather the
        # full (B, S, V) logits (21.5 GB/device at kimi's 163k vocab).
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                              logits.ndim - 1)
        tgt_logit = jnp.sum(
            jnp.where(vocab_iota == targets[..., None], logits, 0.0), axis=-1)
        ll = tgt_logit - logz
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(ll)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = -(ll * mask).sum() / denom
        zl = Z_LOSS * (jnp.square(logz) * mask).sum() / denom
        total = ce + zl
        if self.cfg.moe is not None:
            total = total + self.cfg.moe.router_aux_weight * aux
        metrics = {"loss": total, "ce": ce, "z_loss": zl, "aux": aux,
                   "ft_detected": rep.detected, "ft_corrected": rep.corrected}
        return total, metrics

    def logits(self, params, batch, *, mesh=None):
        out, rep, _, _ = forward(params, self.cfg, batch, mesh=mesh)
        return out, rep

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, *, cache_len: Optional[int] = None):
        cfg = self.cfg
        cache_len = cache_len or cfg.max_seq
        dtype = jnp.dtype(cfg.dtype)

        def one_attn(cross_len=0):
            return init_attn_cache(batch, cfg.attn, cache_len=cache_len,
                                   dtype=dtype, cross_len=cross_len)

        def stack(tree, n):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)

        fam = cfg.family
        if fam == "ssm":
            one = ssm_lib.rwkv_state_init(batch, cfg.d_model, cfg.ssm, dtype)
            return stack(one, cfg.num_layers)
        if fam == "hybrid":
            one = {"attn": one_attn(),
                   "mamba": ssm_lib.mamba_state_init(batch, cfg.d_model,
                                                     cfg.ssm, dtype)}
            return stack(one, cfg.num_layers)
        if fam == "vlm" and cfg.cross_attn_every:
            ce = cfg.cross_attn_every
            n_super = cfg.num_layers // ce
            one = {
                "dense": stack({"attn": one_attn()}, ce - 1),
                "cross_blk": {"attn": one_attn(cross_len=cfg.frontend_tokens)},
            }
            return stack(one, n_super)
        if fam in ("audio", "encdec"):
            one = {"attn": one_attn(cross_len=max(cfg.frontend_tokens, 1))}
            return stack(one, cfg.num_layers)
        one = {"attn": one_attn()}
        return stack(one, cfg.num_layers)

    def prefill(self, params, tokens, cache, *, frontend=None,
                enc_tokens=None, mesh=None, lengths=None, fault=None):
        """Process the prompt, fill caches. Returns (last-token logits, cache).

        ``lengths`` (B,) int32 supports ragged prompts padded to a common
        width: the returned logits are gathered at ``lengths - 1`` per row
        instead of the last column. Causality guarantees the gathered logits
        are unaffected by the padding tokens; the serve engine additionally
        rewinds each slot's cache position to its true length so padded K/V
        slots are masked out of subsequent decode steps.
        """
        batch = {"tokens": tokens}
        if frontend is not None:
            batch["frontend"] = frontend
        if enc_tokens is not None:
            batch["enc_tokens"] = enc_tokens
        logits, rep, _, new_cache = forward(params, self.cfg, batch, mesh=mesh,
                                            cache=cache, mode="prefill",
                                            fault=fault)
        return _last_logits(logits, lengths), rep, new_cache

    def decode_step(self, params, token, cache, *, mesh=None, fault=None):
        """token: (B, 1). Returns (logits (B, V), report, cache)."""
        batch = {"tokens": token}
        logits, rep, _, new_cache = forward(params, self.cfg, batch, mesh=mesh,
                                            cache=cache, mode="decode",
                                            fault=fault)
        return logits[:, -1, :], rep, new_cache

    def extend(self, params, tokens, cache, *, lengths=None, mesh=None,
               fault=None):
        """Unified chunked step: append ``tokens`` (B, S) at the cache's
        current position, attending over the cached context *and* causally
        within the chunk — a multi-token :meth:`decode_step`. This is the
        single entry point behind prefill, prefix-extend, block repair and
        decode (``S = 1``): a thin wrapper over ``forward(mode="decode")``,
        which dispatches on the cache type — contiguous :class:`KVCache`
        rows take the ring path, a :class:`PagedKVCache` takes the fused
        multi-token paged kernel with per-request ``q_len`` chunk raggedness
        (mixed prefill + decode batches in one compiled program). Masked-out
        cache slots contribute exactly zero to the attention accumulators,
        so the result is bit-identical to prefilling the full sequence at
        once (same dtypes) — which is what makes prefix caching and chunked
        prefill exact.

        ``lengths`` (B,) gathers each row's logits at its true (unpadded)
        last token, as in :meth:`prefill`. Contiguous caches must keep
        ``pos + S <= cache_len`` (a ring wrap would clobber context); paged
        caches bound the chunk by their block tables instead. Returns
        (last logits, report, cache).
        """
        batch = {"tokens": tokens}
        logits, rep, _, new_cache = forward(params, self.cfg, batch, mesh=mesh,
                                            cache=cache, mode="decode",
                                            fault=fault)
        return _last_logits(logits, lengths), rep, new_cache

    def score(self, params, tokens, cache, *, mesh=None, fault=None):
        """:meth:`extend` returning the FULL per-row logits ``(B, S, V)``.

        This is the scoring half of the serve engine's propose→score→accept
        contract: the chunk rows are a pending token plus K speculative draft
        tokens, and the acceptance stage needs the target distribution at
        *every* row (row ``j`` conditions on the cached context plus rows
        ``0..j``), not just the last one. Same unified chunked computation as
        :meth:`extend` — ring caches and :class:`PagedKVCache` block pools
        both dispatch through ``forward(mode="decode")`` — so scoring K
        drafts is one EFTA-protected launch, bit-identical per row to
        feeding the same tokens one step at a time.
        """
        batch = {"tokens": tokens}
        logits, rep, _, new_cache = forward(params, self.cfg, batch, mesh=mesh,
                                            cache=cache, mode="decode",
                                            fault=fault)
        return logits, rep, new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
