"""Speculative decoding: the propose→score→accept serve contract (ISSUE 5).

The acceptance bar: greedy speculative decoding is **token-identical** to
the non-speculative engine on both paged backends (the parity oracle),
rejection sampling preserves the target distribution, rejected draft rows
roll back from the paged KV blocks with checksum-verified truncation (the
anti-laundering guard), and SEU campaigns striking the *draft* pass, the
*target* pass, and *mid-rollback* all finish detect→repair→token-identical
with zero silent corruptions.
"""
import dataclasses

import numpy as np
import pytest

from repro.serve.sampling import speculative_accept, target_probs
from repro.serve.scheduler import ContinuousBatchingScheduler, Request


# ---------------------------------------------------------------------------
# acceptance stage (pure numpy, no jax)
# ---------------------------------------------------------------------------

@pytest.mark.quick
def test_speculative_accept_greedy_is_exact_argmax():
    rows = np.array([[0.0, 3.0, 1.0],     # argmax 1
                     [5.0, 0.0, 1.0],     # argmax 0
                     [0.0, 1.0, 9.0]])    # argmax 2
    # drafts match rows 0 and 1 -> both accepted, bonus from row 2
    a, nxt = speculative_accept(rows, [1, 0], temperature=0.0, top_k=0)
    assert (a, nxt) == (2, 2)
    # first draft wrong -> zero accepted, resample = row-0 argmax
    a, nxt = speculative_accept(rows, [2, 0], temperature=0.0, top_k=0)
    assert (a, nxt) == (0, 1)
    # second draft wrong -> one accepted, next = row-1 argmax
    a, nxt = speculative_accept(rows, [1, 2], temperature=0.0, top_k=0)
    assert (a, nxt) == (1, 0)
    # K = 0 degenerates to plain greedy decode
    a, nxt = speculative_accept(rows[:1], [], temperature=0.0, top_k=0)
    assert (a, nxt) == (0, 1)


@pytest.mark.quick
def test_rejection_sampling_preserves_target_distribution():
    """The statistical guarantee speculation rests on: committed tokens are
    distributed exactly as non-speculative samples from the target,
    whatever the proposal. Marginal of (accept draft x, else resample from
    the residual) must equal the target softmax."""
    rng = np.random.default_rng(0)
    logits = np.array([1.2, -0.4, 0.7, 2.1, 0.0], np.float32)
    temperature, top_k = 0.9, 0
    p = target_probs(logits, temperature=temperature, top_k=top_k)
    n = 20000
    for draft_tok in (3, 1):            # a likely and an unlikely proposal
        counts = np.zeros(5)
        accepted = 0
        for _ in range(n):
            a, nxt = speculative_accept(
                logits[None].repeat(2, axis=0), [draft_tok],
                temperature=temperature, top_k=top_k, rng=rng)
            tok = draft_tok if a == 1 else nxt
            counts[tok] += 1
            accepted += a
        emp = counts / n
        np.testing.assert_allclose(emp, p, atol=0.015), (emp, p)
        # acceptance probability of a one-hot proposal is p(draft)
        assert abs(accepted / n - p[draft_tok]) < 0.015


@pytest.mark.quick
def test_rejection_sampling_respects_top_k():
    rng = np.random.default_rng(1)
    logits = np.array([3.0, 2.0, 1.0, 0.0], np.float32)
    for _ in range(300):
        a, nxt = speculative_accept(
            logits[None].repeat(2, axis=0), [3],   # draft outside top-2
            temperature=1.0, top_k=2, rng=rng)
        tok = 3 if a == 1 else nxt
        assert tok in (0, 1)            # top-2 truncation: 2/3 impossible
        assert a == 0                   # p(draft)=0 -> always rejected


# ---------------------------------------------------------------------------
# scheduler: draft budgeting (no jax)
# ---------------------------------------------------------------------------

def _req(rid, admit_order, max_new=100):
    r = Request(rid=rid, prompt=np.asarray([1], np.int32),
                max_new_tokens=max_new)
    r.admit_order = admit_order
    return r


@pytest.mark.quick
def test_plan_chunks_budgets_drafts_after_prompt_surplus():
    sched = ContinuousBatchingScheduler(4, chunk_budget=6)
    a, b, c = _req(0, 0), _req(1, 1), _req(2, 2)
    # a decodes and wants 4 drafts; b is mid-prefill (owes 30); c decodes
    # and wants 4 drafts. Prompt surplus outranks drafts: b drains the
    # budget first, then a (earlier admission) gets the leftover.
    grants, drafts = sched.plan_chunks(
        [(a, 1), (b, 30), (c, 1)], chunk_size=8,
        draft_wants={a.rid: 4, c.rid: 4})
    assert grants == {a.rid: 1, b.rid: 1 + 6, c.rid: 1}
    assert drafts == {a.rid: 0, b.rid: 0, c.rid: 0}
    # no prefill pressure: drafts spend the budget FCFS
    grants, drafts = sched.plan_chunks(
        [(a, 1), (c, 1)], chunk_size=8, draft_wants={a.rid: 4, c.rid: 4})
    assert grants == {a.rid: 1, c.rid: 1}
    assert drafts == {a.rid: 4, c.rid: 2}
    # unbounded budget: everyone drafts up to chunk_size - 1
    sched.chunk_budget = None
    _, drafts = sched.plan_chunks(
        [(a, 1), (c, 1)], chunk_size=4, draft_wants={a.rid: 9, c.rid: 2})
    assert drafts == {a.rid: 3, c.rid: 2}
    # a mid-prefill request never drafts, whatever it asks for
    _, drafts = sched.plan_chunks(
        [(b, 12)], chunk_size=8, draft_wants={b.rid: 4})
    assert drafts == {b.rid: 0}


# ---------------------------------------------------------------------------
# n-gram proposer (no jax)
# ---------------------------------------------------------------------------

@pytest.mark.quick
def test_ngram_proposer_prompt_lookup():
    from repro.serve.draft import NGramProposer
    prop = NGramProposer(max_ngram=3, min_ngram=1)
    # tail bigram (7, 8) occurred earlier, followed by 9, 4
    toks = np.asarray([7, 8, 9, 4, 5, 7, 8], np.int32)
    np.testing.assert_array_equal(prop.propose(0, toks, 2), [9, 4])
    # rightmost match wins: the later (1, 2) -> 6 beats the earlier -> 3
    toks = np.asarray([1, 2, 3, 1, 2, 6, 0, 1, 2], np.int32)
    np.testing.assert_array_equal(prop.propose(0, toks, 1), [6])
    # no earlier occurrence of the tail token -> empty (K = 0 path)
    assert prop.propose(0, np.asarray([1, 2, 3], np.int32), 4).size == 0
    assert prop.propose(0, np.asarray([1, 2, 1], np.int32), 0).size == 0


# ---------------------------------------------------------------------------
# engine level (jax; gpt2-smoke)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cold_params = model.init(jax.random.PRNGKey(7))
    rng = np.random.default_rng(0)
    # ragged batch mixing repetitive prompts (the ngram proposer's regime)
    # with random ones (mostly-rejected proposals), more requests than slots
    prompts = []
    for i, t in enumerate((6, 17, 21, 9, 26)):
        if i % 2 == 0:
            pat = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
            prompts.append(np.tile(pat, -(-t // 3))[:t])
        else:
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (t,)).astype(np.int32))
    return cfg, model, params, cold_params, prompts


def _paged(model, params, **kw):
    from repro.serve import PagedServeEngine
    kw.setdefault("n_slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("block_size", 16)
    kw.setdefault("chunk_size", 16)
    return PagedServeEngine(model, params, **kw)


def _run(eng, prompts, gen=8, **submit_kw):
    rids = [eng.submit(p, max_new_tokens=gen, **submit_kw) for p in prompts]
    outs = eng.run()
    return [list(outs[r]) for r in rids]


def test_greedy_speculative_parity_matrix(setup):
    """The parity oracle: greedy speculative decoding must be token-
    identical to the non-speculative engine across both backends x
    K in {1, 2, 4} x a ragged repetitive/random batch — acceptances,
    rejections and KV rollbacks included."""
    cfg, model, params, _, prompts = setup
    ref = _run(_paged(model, params), prompts)
    for kernel in ("gather", "fused"):
        for k in (1, 2, 4):
            eng = _paged(model, params, kernel=kernel, speculate="ngram",
                         draft_len=k)
            got = _run(eng, prompts)
            assert got == ref, f"kernel={kernel} K={k}"
            ps = eng.paged_stats
            assert ps.spec_proposed_tokens > 0, \
                f"kernel={kernel} K={k} never speculated"
            if k > 1:
                assert ps.spec_rolled_back_rows > 0, \
                    f"kernel={kernel} K={k} never rolled back"
            assert ps.kv_detected_blocks == 0     # no false positives


def test_draft_model_parity_and_acceptance(setup):
    """Draft-model proposer through the EFTA path: a self-draft (draft ==
    target) accepts ~every token and cuts the step count; a cold draft
    rejects ~everything; both are token-identical to the baseline."""
    cfg, model, params, cold_params, prompts = setup
    base = _paged(model, params, kernel="fused")
    ref = _run(base, prompts[:3])
    for kernel in ("gather", "fused"):
        eng = _paged(model, params, kernel=kernel, speculate="draft",
                     draft_len=4, draft_model=model, draft_params=params)
        assert _run(eng, prompts[:3]) == ref, kernel
        assert eng.acceptance_rate > 0.9
        if kernel == "fused":
            assert eng.stats.steps < base.stats.steps   # fewer launches
        st = eng.telemetry.requests[0]
        assert st.draft_proposed > 0
        assert st.acceptance_rate > 0.5
    eng = _paged(model, params, kernel="fused", speculate="draft",
                 draft_len=4, draft_model=model, draft_params=cold_params)
    assert _run(eng, prompts[:3]) == ref
    assert eng.acceptance_rate < 0.5
    assert eng.paged_stats.spec_rolled_back_rows > 0


def test_speculation_respects_chunk_budget(setup):
    """Satellite: draft rows spend only leftover chunk budget — a decoding
    request keeps its token/step while a long prompt prefills, and the
    admission is not starved by speculation."""
    cfg, model, params, _, _ = setup
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
    short = np.tile(pat, 3)
    long_p = rng.integers(0, cfg.vocab_size, (40,)).astype(np.int32)
    eng = _paged(model, params, kernel="fused", speculate="ngram",
                 draft_len=4, chunk_budget=4, cache_len=96)
    r_short = eng.submit(short, max_new_tokens=6)
    eng.step()
    r_long = eng.submit(long_p, max_new_tokens=2)
    short_req = next(r for r in eng.scheduler.active_rows()
                     if r.rid == r_short)
    gen_trace = []
    while not short_req.is_done():
        eng.step()
        gen_trace.append(short_req.num_generated)
    # the decode advanced every step (speculation may add more per step,
    # never fewer), and the long prompt is still mid-prefill
    assert all(b > a for a, b in zip(gen_trace, gen_trace[1:]))
    long_req = next((r for r in eng.scheduler.active_rows()
                     if r.rid == r_long), None)
    assert long_req is not None and long_req.num_generated == 0
    eng.run()

    # parity for the same pair without a budget
    ref_eng = _paged(model, params, kernel="fused", cache_len=96)
    ra = ref_eng.submit(short, max_new_tokens=6)
    rb = ref_eng.submit(long_p, max_new_tokens=2)
    ref = ref_eng.run()
    spec_eng = _paged(model, params, kernel="fused", speculate="ngram",
                      draft_len=4, cache_len=96)
    sa = spec_eng.submit(short, max_new_tokens=6)
    sb = spec_eng.submit(long_p, max_new_tokens=2)
    got = spec_eng.run()
    assert list(got[sa]) == list(ref[ra])
    assert list(got[sb]) == list(ref[rb])


# ---------------------------------------------------------------------------
# fault campaigns: draft pass, target pass, mid-rollback
# ---------------------------------------------------------------------------

def test_target_pass_seu_during_speculation(setup):
    """A detect-mode compute SEU striking the scoring (target) pass of a
    speculative step: detected by EFTA, the step retries clean, tokens are
    identical to the clean run — and the new telemetry split shows
    'detected once, then retried clean' (redetected == 0)."""
    import jax
    from repro.core import FaultSpec, Site
    from repro.models import build_model
    from repro.serve import batch_faults
    cfg, _, _, _, prompts = setup
    det_cfg = dataclasses.replace(
        cfg, ft=dataclasses.replace(cfg.ft, mode="detect"))
    model = build_model(det_cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec_kw = dict(speculate="draft", draft_len=3, draft_model=model,
                   draft_params=params)

    for kernel in ("gather", "fused"):
        clean = _paged(model, params, kernel=kernel, **spec_kw)
        ref = _run(clean, prompts[:2], gen=6)
        eng = _paged(model, params, kernel=kernel, **spec_kw)
        f = FaultSpec.single(Site.GEMM2, block=0, batch=0, head=1, row=0,
                             col=3, bit=28)
        rids = [eng.submit(p, max_new_tokens=6) for p in prompts[:2]]
        # strike several early steps: the gather backend speculates from
        # step 0 (and, at acceptance ~1, drains in ~2 steps); the fused
        # backend prefills through steps 0-1 and speculates from step 2 —
        # either way at least one injection lands on a speculative scoring
        # pass, and every injection must be detected and retried clean
        faults = {i: batch_faults(2, {0: f, 1: f}) for i in (0, 1, 2)}
        outs = eng.run(faults_by_step=faults)
        assert [list(outs[r]) for r in rids] == ref, kernel
        assert eng.stats.retries >= 1
        hit = [st for st in eng.telemetry.requests.values()
               if sum(st.detected[:5])]
        assert hit, "SEU was not detected"
        for st in hit:
            # detected once, retried clean: the retry re-detected nothing
            assert sum(st.redetected) == 0
            assert st.retries >= 1


def test_draft_pass_seu_detected_and_harmless(setup):
    """A detect-mode SEU striking the *draft model's* forward: the draft
    pass's own EFTA scheme detects it, the proposal attempt retries clean,
    and the committed tokens are identical — a flipped bit in the draft
    pass can only ever cost a rejected draft, never a wrong token."""
    import jax
    from repro.core import FaultSpec, Site
    from repro.models import build_model
    cfg, _, _, _, prompts = setup
    det_cfg = dataclasses.replace(
        cfg, ft=dataclasses.replace(cfg.ft, mode="detect"))
    model = build_model(det_cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec_kw = dict(speculate="draft", draft_len=3, draft_model=model,
                   draft_params=params)

    clean = _paged(model, params, kernel="fused", **spec_kw)
    ref = _run(clean, prompts[:2], gen=6)

    eng = _paged(model, params, kernel="fused", **spec_kw)
    rids = [eng.submit(p, max_new_tokens=6) for p in prompts[:2]]
    struck = {"n": 0}
    orig_step = eng.step

    def step_with_draft_fault(faults=None):
        if eng.stats.steps == 2 and not struck["n"]:
            eng._proposer.fault_next = FaultSpec.single(
                Site.GEMM1, block=0, batch=0, head=1, row=0, col=2, bit=28)
            struck["n"] += 1
        return orig_step(faults)

    eng.step = step_with_draft_fault
    while eng.scheduler.has_work:
        eng.step()
    outs = {r.rid: list(r.generated) for r in eng.scheduler.finished}
    assert [outs[r] for r in rids] == ref
    assert struck["n"] == 1
    draft_stats = eng._proposer.stats
    assert draft_stats.detected >= 1
    assert draft_stats.retries >= 1
    hit = [st for st in eng.telemetry.requests.values()
           if sum(st.draft_detected[:5])]
    assert hit, "draft-pass SEU not recorded in per-request telemetry"
    assert all(st.draft_retries >= 1 for st in hit)


@pytest.mark.parametrize("kernel", ["gather", "fused"])
def test_mid_rollback_corruption_is_never_laundered(setup, kernel):
    """The anti-laundering guard: a resident bit flip landing between the
    scoring step's verify and the KV rollback's checksum re-stamp must be
    caught by the rollback's pre-restamp verification, repaired by block
    re-prefill, and leave the final tokens identical — re-stamping from
    corrupted content would have made it permanently silent."""
    cfg, model, params, cold_params, prompts = setup
    # cold draft model: every proposal is rejected -> every spec step
    # rolls back, so the hook's strike always lands mid-rollback
    spec_kw = dict(speculate="draft", draft_len=4, draft_model=model,
                   draft_params=cold_params)
    ref = _run(_paged(model, params, kernel=kernel), [prompts[1]])

    eng = _paged(model, params, kernel=kernel, **spec_kw)
    fired = {"n": 0}

    def strike(e):
        if fired["n"]:
            return
        req = [r for r in e.scheduler.active_rows() if not r.is_done()][0]
        pos = int(e._pos[req.slot])          # already rewound to keep_pos
        j = pos // e.block_size
        if j < len(req.block_ids) and pos % e.block_size > 0:
            e.inject_kv_fault(layer=0, block=req.block_ids[j], head=0,
                              row=(pos % e.block_size) - 1, col=2, bit=27,
                              into="k")
            fired["n"] += 1

    eng._pre_rollback_hook = strike
    got = _run(eng, [prompts[1]])
    assert got == ref
    assert fired["n"] == 1
    assert eng.paged_stats.rollback_detected_blocks >= 1
    assert eng.paged_stats.kv_repaired_blocks >= 1


def test_resident_kv_seu_during_speculation(setup):
    """Site.KV resident-state flips striking live blocks while the engine
    speculates: detected at read time by the scoring step's verification,
    repaired by block re-prefill, token-identical — zero silent
    corruptions through the speculative path."""
    cfg, model, params, _, prompts = setup
    for kernel in ("gather", "fused"):
        spec_kw = dict(speculate="draft", draft_len=3, draft_model=model,
                       draft_params=params)
        ref = _run(_paged(model, params, kernel=kernel, **spec_kw),
                   [prompts[1]], gen=16)
        eng = _paged(model, params, kernel=kernel, **spec_kw)
        rid = eng.submit(prompts[1], max_new_tokens=16)
        eng.step()
        eng.step()
        req = next(r for r in eng.scheduler.active_rows())
        assert not req.is_done()        # corruption must still be read
        eng.inject_kv_fault(layer=1, block=req.block_ids[0], head=0, row=3,
                            col=5, bit=27, into="v")
        outs = eng.run()
        assert list(outs[rid]) == ref[0], kernel
        assert eng.paged_stats.kv_detected_blocks >= 1
        assert eng.paged_stats.kv_repaired_blocks >= 1


@pytest.mark.quick
def test_speculative_quick_smoke(setup):
    """Quick-tier guard: speculation on the fused backend stays token-
    identical to the baseline with the engine still at <= 2 compiled step
    programs; the self-draft proposer commits accepted drafts (acceptance
    ~1 by construction), the ngram proposer survives rejections."""
    cfg, model, params, _, prompts = setup
    ref = _run(_paged(model, params, kernel="fused"), [prompts[0]])
    eng = _paged(model, params, kernel="fused", speculate="ngram",
                 draft_len=3)
    got = _run(eng, [prompts[0]])
    assert got == ref
    assert eng.paged_stats.spec_proposed_tokens > 0
    assert eng._step_fused._cache_size() <= 2
    eng = _paged(model, params, kernel="fused", speculate="draft",
                 draft_len=3, draft_model=model, draft_params=params)
    got = _run(eng, [prompts[0]])
    assert got == ref
    assert eng.paged_stats.spec_accepted_tokens > 0
    assert eng.acceptance_rate > 0.9
    assert eng._step_fused._cache_size() <= 2
