"""Paged KV-cache serving: one shared system prompt, prefilled once, plus a
resident-state SEU healed by block re-prefill.

Four requests share a 32-token system prompt. The first admission prefills
it and registers its blocks in the prefix cache; every later admission
hash-chain-matches those blocks and only computes its own suffix. Mid-run a
bit flip strikes a *shared* KV block in HBM — the block checksums catch it at
the next gather, the engine re-prefills just that block (healing every
request mapping it), retries the step, and finishes token-identical to a
clean run.

  PYTHONPATH=src python examples/paged_prefix_serving.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import PagedServeEngine

cfg = get_config("gpt2-smoke")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

system_prompt = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
prompts = [np.concatenate([system_prompt,
                           rng.integers(0, cfg.vocab_size, (n,))
                           .astype(np.int32)]) for n in (4, 6, 5, 7)]

print(f"serving {cfg.name} from a paged KV pool "
      f"(block_size=16, shared system prompt = 32 tokens)")


def serve(inject_kv_fault: bool):
    eng = PagedServeEngine(model, params, n_slots=2, cache_len=64,
                           block_size=16, num_blocks=24)
    rids = []
    # staggered arrival: the first request seeds the prefix cache
    rids.append(eng.submit(prompts[0], max_new_tokens=6))
    eng.step()
    for p in prompts[1:]:
        rids.append(eng.submit(p, max_new_tokens=6))
    eng.step()
    if inject_kv_fault:
        # SEU in HBM: flip an exponent bit of a *shared* prefix block
        shared_block = next(r for r in eng.scheduler.active_rows()
                            if not r.is_done()).block_ids[0]
        eng.inject_kv_fault(layer=1, block=shared_block, head=0, row=2,
                            col=3, bit=28, into="k")
    while eng.scheduler.has_work:
        eng.step()
    outs = {r.rid: np.asarray(r.generated) for r in eng.scheduler.finished}
    return eng, [outs[r] for r in rids]


clean_eng, clean = serve(inject_kv_fault=False)
fault_eng, healed = serve(inject_kv_fault=True)

xs = fault_eng.pool.prefix.stats
ps = fault_eng.paged_stats
print(f"prefix cache: {xs.hit_tokens}/{xs.lookup_tokens} prompt tokens "
      f"served from resident blocks ({len(prompts) - 1} of {len(prompts)} "
      f"requests skipped the system-prompt prefill)")
print(f"resident KV SEU: detected={ps.kv_detected_blocks} block(s) at read "
      f"time, repaired={ps.kv_repaired_blocks} by block re-prefill")
assert xs.hit_tokens >= 32 * (len(prompts) - 1)
assert ps.kv_detected_blocks >= 1 and ps.kv_repaired_blocks >= 1
for a, b in zip(clean, healed):
    assert np.array_equal(a, b)
print("OK: every request's tokens are identical to the clean run — the "
      "corruption never reached an output.")
