"""gpt2 (paper Table 3): 12L 12H head_dim=64, d_model=768, learned positions,
LayerNorm + GELU."""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="gpt2", family="dense",
    num_layers=12, d_model=768, d_ff=3072, vocab_size=50257,
    attn=AttnCfg(num_heads=12, num_kv_heads=12, head_dim=64, pos="learned"),
    norm="layernorm", glu=False, act="gelu", max_seq=1024,
    source="paper Table 3",
)
