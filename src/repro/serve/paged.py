"""Fault-tolerant paged KV-cache serve engine (vLLM-style block pool).

Replaces the per-slot ring caches with a **global block pool**: KV lives in
fixed-size blocks ``(num_layers, num_blocks, Hkv, block_size, head_dim)``
addressed through per-request block tables, so long prompts draw from a
shared pool, identical prompt prefixes are stored once (hash-chain prefix
cache + refcounted copy-on-write sharing in ``repro.serve.blocks``), and a
preempted request frees exactly its blocks.

Two backends (``kernel=``), one program shape each:

  * ``"fused"`` runs a **unified batched step**: every engine iteration
    builds one mixed batch in which each slot feeds a chunk of up to
    ``chunk_size`` tokens — new prompts prefill chunk by chunk, resumed
    prompts extend, repairs re-prefill a block, and steady-state requests
    decode one token — all through the *same* multi-token fused
    paged-attention Pallas kernel (``repro.kernels.efta_paged`` via
    ``models.attention.PagedKVCache``). There are no per-bucket prefill
    programs and no separate extend jit: XLA compiles exactly two programs
    (chunk width ``chunk_size`` and width 1) regardless of prompt lengths.
    A scheduler ``chunk_budget`` bounds the prompt tokens per step so long
    prompts never head-of-line-block other requests' decodes.
  * ``"gather"`` (portable baseline) materializes each slot's table into the
    contiguous layout the ring engine already decodes
    (``repro.kernels.ops.gather_block_kv``) and vmaps the pure-JAX EFTA
    path; prompt prefill / prefix-extend / repair run through ONE
    fixed-width chunked ``Model.extend`` program (the former power-of-two
    prompt buckets — one compiled program per bucket size — are retired).

Both compute the same values at the same positions, so the paged engine is
**token-identical** to the ring engine and to per-request sequential
decoding on either backend.

Fault story (the paper's resident-state gap): EFTA protects the attention
*computation*, but KV sitting in HBM across thousands of decode steps is
unprotected memory — one SEU in a cached K row silently poisons every later
token. Here every block carries an ABFT-style checksum pair
(``repro.core.checksum.encode_kv`` along the token axis) written on append
and **verified at every read into a decode step** — on the gathered blocks
outside the kernel (``gather``), or in the same kernel pass that streams the
block (``fused``) — so a resident bit flip is detected *at read time* (site
``kv`` in the telemetry 6-vector). The repair is surgical: only the
poisoned block is re-prefilled — through the same unified chunked step
(``fused``: a single-slot chunk with the position rewound to the block
start, so repair can never recompile even under pool pressure) or the
fixed-width extend (``gather``) — then the step retries; a repaired shared
prefix block heals every request mapping it. ``kv_verify="stamped"``
amortizes the gather backend's checksum folds over per-block generation
stamps (``serve.blocks``): steady-state decode folds ~one tail block per
slot instead of the whole table, trading deferred detection of flips that
land in verified-and-untouched blocks. ``scrub_interval`` bounds that
deferral: every N committed steps a background scrub re-folds the
oldest-verified live blocks (``scrub_batch`` per pass), so a flip in a
stamped block is caught within ``interval * ceil(live / batch)`` steps
instead of waiting for the block's next write.

Prefix caching rides the same machinery: a prompt whose leading full blocks
hash-chain-match resident blocks skips straight to chunked extension over
its suffix (bit-identical to full prefill — masked cache slots contribute
exactly zero). Since PR 4 **decode-filled blocks register too**: whenever a
request's generation fills a block, the block joins the token-hash chain, so
n-best / self-consistency resampling of the same prompt + continuation
prefix hits cache instead of re-prefilling (appends to a registered block
copy-on-write-split as before — full blocks are immutable).

Since PR 5 the step is the general **propose→score→accept** contract
(speculative decoding): each slot proposes K candidate tokens
(``repro.serve.draft`` — n-gram prompt lookup or an EFTA-protected draft
model; K = 0 degenerates to plain decode, a prompt suffix to prefill), the
unified chunked program scores pending + drafts in one protected launch
returning per-row logits, and the acceptance stage
(``repro.serve.sampling.speculative_accept``) commits the longest valid
prefix. Rejected rows already appended to blocks are rewound by
fault-tolerant ``kv_len`` truncation (``models.attention.paged_rollback``):
touched tail blocks re-verify against their PRE-rollback checksums before
their checksums are re-generated over the truncated content, so corruption
landing mid-rollback is detected and repaired, never laundered into a
consistent state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checksum as cks
from repro.core.fault import FaultSpec, flip_bit_at
from repro.kernels.efta_paged import paged_fault_descriptor
from repro.kernels.ops import gather_block_kv
from repro.models.api import Model
from repro.models.attention import KVCache, PagedKVCache, paged_rollback
from repro.serve.blocks import NULL_BLOCK, BlockPool, PrefixCache
from repro.serve.cache import add_unit_batch, drop_unit_batch
from repro.serve.draft import build_proposer
from repro.serve.engine import ServeEngine
from repro.serve.sampling import (request_key, sample_tokens,
                                  speculative_accept)
from repro.serve.scheduler import Request


class PagedKVState(NamedTuple):
    """Device-resident block pool. Row 0 of every array is the null block
    (scratch for padded table entries — never verified, never read back)."""

    k: jax.Array     # (L, num_blocks+1, Hkv, block_size, head_dim)
    v: jax.Array
    kc1: jax.Array   # (L, num_blocks+1, Hkv, check_stride, head_dim)
    kc2: jax.Array
    vc1: jax.Array
    vc2: jax.Array


@dataclasses.dataclass
class PagedCacheStats:
    kv_detected_blocks: int = 0    # block-checksum mismatches seen at read
    kv_repaired_blocks: int = 0    # blocks healed by re-prefill
    kv_verified_blocks: int = 0    # read-time fold verifications performed
    kv_verify_skips: int = 0       # verifies skipped by generation stamps
    kv_scrubbed_blocks: int = 0    # blocks re-folded by the background scrub
    preemptions: int = 0
    chunked_prefill_tokens: int = 0  # prompt tokens fed through mixed steps
    # speculative decoding (propose→score→accept)
    spec_steps: int = 0            # committed steps that scored >= 1 draft
    spec_proposed_tokens: int = 0  # draft tokens scored by the target
    spec_accepted_tokens: int = 0  # draft tokens committed
    spec_rolled_back_rows: int = 0  # rejected KV rows truncated by rollback
    rollback_detected_blocks: int = 0  # corruption caught by the rollback
    #                                    pre-restamp (anti-laundering) guard


class PagedKVPool:
    """Device arrays + host allocators for the paged cache.

    Mirrors :class:`repro.serve.cache.KVCachePool`'s slot interface (the
    engine still decodes a fixed ``n_slots``-wide batch) and adds the block
    pool, block tables and prefix cache behind it.
    """

    def __init__(self, model: Model, n_slots: int, cache_len: int,
                 block_size: int, num_blocks: int, check_stride: int):
        cfg = model.cfg
        a = cfg.attn
        if cache_len % block_size:
            raise ValueError("cache_len must be a multiple of block_size")
        dtype = jnp.dtype(cfg.dtype)
        L = cfg.num_layers
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_blocks = cache_len // block_size
        self.num_blocks = num_blocks
        self.check_stride = check_stride
        kv_shape = (L, num_blocks + 1, a.num_kv_heads, block_size, a.head_dim)
        ck_shape = (L, num_blocks + 1, a.num_kv_heads, check_stride,
                    a.head_dim)
        self.state = PagedKVState(
            k=jnp.zeros(kv_shape, dtype), v=jnp.zeros(kv_shape, dtype),
            kc1=jnp.zeros(ck_shape, dtype), kc2=jnp.zeros(ck_shape, dtype),
            vc1=jnp.zeros(ck_shape, dtype), vc2=jnp.zeros(ck_shape, dtype))
        self.blocks = BlockPool(num_blocks, block_size)
        self.prefix = PrefixCache(self.blocks)
        self._free_slots: List[int] = list(range(n_slots))

    # -- slot lifetime (same contract as KVCachePool) -----------------------
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def alloc(self) -> Optional[int]:
        return self._free_slots.pop(0) if self._free_slots else None

    def release(self, slot: int) -> None:
        if slot in self._free_slots:
            raise ValueError(f"slot {slot} double-freed")
        self._free_slots.append(slot)
        self._free_slots.sort()


def _largest_divisor_leq(n: int, cap: int) -> int:
    s = min(cap, n)
    while n % s:
        s -= 1
    return s


class PagedServeEngine(ServeEngine):
    """Continuous-batching engine over a checksummed paged block pool.

    Drop-in for :class:`ServeEngine` (same ``submit``/``step``/``run``), plus
    ``inject_kv_fault`` for resident-state SEU campaigns. ``num_blocks``
    defaults to ring-equivalent capacity (``n_slots * cache_len /
    block_size``); give it headroom to keep evicted prompts' prefix blocks
    resident for longer.

    ``kernel``: ``"gather"`` (portable default) or ``"fused"`` (block-table
    Pallas kernel driving the unified mixed prefill/decode batched step;
    interpret mode off-TPU). ``chunk_size`` is the multi-token step's chunk
    width (>= ``block_size`` so one chunk re-prefills one block; default
    ``2 * block_size``); ``chunk_budget`` caps prompt tokens per mixed step
    (None = unbounded) so prompts never starve decodes. ``kv_verify``:
    ``"always"`` (full read-time coverage, default) or ``"stamped"``
    (generation-stamped fold skipping on the gather backend; the fused
    kernel's in-loop verify is already ~free) — with ``scrub_interval > 0``
    a background scrub re-folds the ``scrub_batch`` oldest-verified live
    blocks every that many committed steps, bounding the stamped policy's
    deferred-detection window. The fused backend reads its checksum
    threshold from ``repro.core.checksum.kv_block_threshold`` — a custom
    ``check_threshold`` only steers the gather-side verification.

    ``speculate`` turns the step into the full propose→score→accept
    contract: ``"ngram"`` self-drafts by prompt lookup, ``"draft"`` decodes
    a small draft model (``draft_model``/``draft_params``) through the same
    EFTA path; up to ``draft_len`` draft rows per slot ride the scored
    chunk (padded to the chunk width — the ≤ 2-compiled-programs invariant
    holds with speculation on), the acceptance stage commits the longest
    valid prefix, and rejected rows roll back from the paged blocks with
    checksum-verified truncation. Greedy speculation is token-identical to
    ``speculate="off"``.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 8,
                 cache_len: Optional[int] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 check_stride: Optional[int] = None,
                 check_threshold: Optional[float] = None,
                 max_retries: int = 2, retry_on_detect: bool = True,
                 chunk_size: Optional[int] = None,
                 chunk_budget: Optional[int] = None,
                 kernel: str = "gather", kv_verify: str = "always",
                 scrub_interval: int = 0, scrub_batch: int = 4,
                 speculate: str = "off", draft_len: int = 4,
                 draft_model: Optional[Model] = None, draft_params=None):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if kernel not in ("gather", "fused"):
            raise ValueError(f"kernel must be 'gather' or 'fused'; "
                             f"got {kernel!r}")
        if kv_verify not in ("always", "stamped"):
            raise ValueError(f"kv_verify must be 'always' or 'stamped'; "
                             f"got {kv_verify!r}")
        cl = cache_len or model.cfg.max_seq
        cl = -(-cl // block_size) * block_size     # round up to block grid
        self.block_size = block_size
        self.max_blocks = cl // block_size
        self.num_blocks = num_blocks or n_slots * self.max_blocks
        self.check_stride = check_stride or _largest_divisor_leq(block_size, 8)
        if block_size % self.check_stride:
            raise ValueError("check_stride must divide block_size")
        if check_threshold is None:
            check_threshold = cks.kv_block_threshold(model.cfg.dtype)
        self.check_threshold = check_threshold
        self.kernel = kernel
        self.kv_verify = kv_verify
        self.chunk_size = min(chunk_size or 2 * block_size, cl)
        if self.chunk_size < block_size:
            raise ValueError(
                f"chunk_size ({self.chunk_size}) must be >= block_size "
                f"({block_size}): block repair re-prefills one block per "
                f"chunk")
        if scrub_interval and kernel == "fused":
            raise ValueError(
                "scrub_interval is a gather/stamped amortization: the fused "
                "kernel re-verifies every streamed block in-loop each step, "
                "so a background scrub would never run there")
        self.scrub_interval = scrub_interval
        self.scrub_batch = scrub_batch
        if speculate not in ("off", "ngram", "draft"):
            raise ValueError(f"speculate must be 'off', 'ngram' or 'draft'; "
                             f"got {speculate!r}")
        self.speculate = speculate
        if speculate == "off":
            self.draft_len = 0
            self._proposer = None
        else:
            if draft_len < 1:
                raise ValueError("speculation needs draft_len >= 1")
            # the scored chunk is the pending token + K drafts, padded to
            # the chunk width (the ≤2-compiled-programs invariant)
            self.draft_len = min(draft_len, self.chunk_size - 1)
            if self.draft_len < 1:
                raise ValueError(
                    f"chunk_size ({self.chunk_size}) leaves no room for "
                    f"draft rows; speculation needs chunk_size >= 2")
            self._proposer = build_proposer(
                speculate, n_slots=n_slots, cache_len=cl,
                chunk_size=self.chunk_size, draft_model=draft_model,
                draft_params=draft_params)
        # fault-campaign hook: called after the scoring step committed and
        # before the KV rollback runs — lets tests strike resident state
        # mid-rollback and assert the pre-restamp guard catches it
        self._pre_rollback_hook = None
        super().__init__(model, params, n_slots=n_slots, cache_len=cl,
                         max_retries=max_retries,
                         retry_on_detect=retry_on_detect)
        self.scheduler.chunk_budget = chunk_budget
        self.paged_stats = PagedCacheStats()
        # host mirrors of the device block tables / positions, plus the
        # per-slot feed queue: tokens whose KV is not yet resident — the
        # prompt suffix while prefilling, exactly the pending token once
        # decoding. One rule drives the unified step: feed up to chunk_size
        # queue tokens; when the queue drains, sample (the sample becomes
        # the next queue entry).
        self._bt = np.zeros((n_slots, self.max_blocks), np.int32)
        self._pos = np.zeros((n_slots,), np.int32)
        self._queue: List[List[int]] = [[] for _ in range(n_slots)]
        self._admit_seq = 0
        # consecutive steps abandoned because corruption outlived repair
        self._poisoned_steps = 0
        # read-time verification selector: "always" folds every table entry;
        # "stamped" (gather backend only) folds just the entries whose block
        # generation moved since their last verified read, padded to a small
        # fixed width (full fallback when a step needs more — e.g. right
        # after admission). The fused kernel verifies in-loop for free.
        self._sel_all = np.broadcast_to(
            np.arange(self.max_blocks, dtype=np.int32),
            (n_slots, self.max_blocks)).copy()
        self._sel_width = min(4, self.max_blocks)
        if kernel == "fused":
            self._step_fused = jax.jit(self._step_fused_fn)
        else:
            self._score = jax.jit(self._score_fn)
        self._gather_ctx = jax.jit(self._gather_ctx_fn)
        self._extend = jax.jit(self._extend_fn)
        self._scatter = jax.jit(self._scatter_fn)
        self._scrub = jax.jit(self._scrub_fn)
        self._copy_block = jax.jit(self._copy_block_fn)
        self._rollback = jax.jit(self._rollback_fn)
        self._flip = jax.jit(self._flip_fn, static_argnames=("into",))

    def _make_pool(self) -> PagedKVPool:
        return PagedKVPool(self.model, self.n_slots, self.cache_len,
                           self.block_size, self.num_blocks,
                           self.check_stride)

    # -- jitted computations ------------------------------------------------

    def _verify_gathered(self, state: PagedKVState, bt: jax.Array,
                         sel: Optional[jax.Array] = None
                         ) -> Tuple[Any, Any, jax.Array]:
        """Gather K/V blocks for table ``bt`` (..., mb) and verify blocks
        against their resident checksums. Returns (k, v, bad): the contiguous
        KV views attention consumes, and ``bad`` (..., mb) flagging real
        (non-null) blocks with a mismatch in either operand's checksum pair.

        ``sel`` (ns, K) optionally restricts the fold recomputation to K
        table entries per slot (-1 = none): the generation-stamped policy's
        savings come from folding only the blocks whose content could have
        moved since their last verified read, instead of the whole table.
        """
        kraw, kg = gather_block_kv(state.k, bt)
        vraw, vg = gather_block_kv(state.v, bt)
        s = self.check_stride
        thr = self.check_threshold
        if sel is None:
            bad_k, _ = cks.verify_block(
                kraw, cks.Checksums(state.kc1[:, bt], state.kc2[:, bt]), s,
                threshold=thr)
            bad_v, _ = cks.verify_block(
                vraw, cks.Checksums(state.vc1[:, bt], state.vc2[:, bt]), s,
                threshold=thr)
            # reduce (L, ..., mb, Hkv) over layers and heads -> (..., mb)
            bad = jnp.any(bad_k | bad_v, axis=(0, -1)) & (bt > NULL_BLOCK)
            return kg, vg, bad
        selc = jnp.clip(sel, 0, None)                       # (ns, K)
        valid = sel >= 0
        btv = jnp.take_along_axis(bt, selc, axis=1)         # (ns, K)
        idx = selc[None, :, :, None, None, None]
        ksel = jnp.take_along_axis(kraw, idx, axis=2)
        vsel = jnp.take_along_axis(vraw, idx, axis=2)
        bad_k, _ = cks.verify_block(
            ksel, cks.Checksums(state.kc1[:, btv], state.kc2[:, btv]), s,
            threshold=thr)
        bad_v, _ = cks.verify_block(
            vsel, cks.Checksums(state.vc1[:, btv], state.vc2[:, btv]), s,
            threshold=thr)
        bad_sel = (jnp.any(bad_k | bad_v, axis=(0, -1))
                   & (btv > NULL_BLOCK) & valid)            # (ns, K)
        ns = bt.shape[0]
        bad = jnp.zeros(bt.shape, jnp.int32).at[
            jnp.arange(ns)[:, None], selc].max(bad_sel.astype(jnp.int32))
        return kg, vg, bad > 0

    def _decode_fn(self, params, tokens, state, bt, pos, faults, temps,
                   topks, seeds, rids, counters, verify_sel):
        """One batched paged decode step on the gather backend: gather-by-
        block-table, read-time checksum verify, vmapped EFTA decode, append
        + checksum update."""
        cfg = self.model.cfg
        a = cfg.attn
        L, ns, bs = cfg.num_layers, self.n_slots, self.block_size
        kg, vg, bad = self._verify_gathered(state, bt, verify_sel)
        czero = jnp.zeros((L, ns, a.num_kv_heads, 1, a.head_dim), kg.dtype)
        cache = {"attn": KVCache(
            k=kg, v=vg, pos=jnp.broadcast_to(pos[None], (L, ns)),
            ck=czero, cv=czero)}
        axes = jax.tree.map(lambda _: 1, cache)

        def one(tok, row, f):
            logits, rep, new_row = self.model.decode_step(
                params, tok[None, None], add_unit_batch(row), fault=f)
            return logits[0], rep, drop_unit_batch(new_row)

        logits, rep, new_cache = jax.vmap(
            one, in_axes=(0, axes, 0), out_axes=(0, 0, axes))(
                tokens, cache, faults)

        # append: pull the row each slot just wrote at its position and
        # scatter it into that slot's tail block, then refresh the tail
        # block's checksums (appends are writes; verification happens at the
        # *next* gather).
        idx = pos[None, :, None, None, None]
        row_k = jnp.take_along_axis(new_cache["attn"].k, idx, axis=3)[..., 0, :]
        row_v = jnp.take_along_axis(new_cache["attn"].v, idx, axis=3)[..., 0, :]
        tgt = jnp.take_along_axis(bt, (pos // bs)[:, None], axis=1)[:, 0]
        off = pos % bs
        new_k = state.k.at[:, tgt, :, off, :].set(row_k.transpose(1, 0, 2, 3))
        new_v = state.v.at[:, tgt, :, off, :].set(row_v.transpose(1, 0, 2, 3))
        ck = cks.encode_kv(new_k[:, tgt], self.check_stride)
        cv = cks.encode_kv(new_v[:, tgt], self.check_stride)
        new_state = PagedKVState(
            k=new_k, v=new_v,
            kc1=state.kc1.at[:, tgt].set(ck.c1),
            kc2=state.kc2.at[:, tgt].set(ck.c2),
            vc1=state.vc1.at[:, tgt].set(cv.c1),
            vc2=state.vc2.at[:, tgt].set(cv.c2))

        def key_of(seed, rid, counter):
            return jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)

        keys = jax.vmap(key_of)(seeds, rids, counters)
        next_tokens = sample_tokens(logits, temperature=temps, top_k=topks,
                                    keys=keys)
        return next_tokens, rep, bad, new_state

    def _step_fused_fn(self, params, tokens, state, bt, pos, q_lens, faults,
                       temps, topks, seeds, rids, counters):
        """One unified batched step on the fused backend: every slot feeds a
        chunk of ``q_lens[slot]`` tokens (0 = idle, 1 = decode, more =
        chunked prefill / prefix-extend / block repair / a pending token
        plus speculative draft rows) and the model's attention consumes the
        block pool *directly* through
        :class:`repro.models.attention.PagedKVCache` — one natively batched
        ragged multi-token kernel launch per layer, no contiguous gather,
        resident block checksums verified inside the kernel's KV streaming
        loop, chunk-appended rows checksum-encoded in the same step. The
        fault batch is translated to the kernel's single-SEU descriptor
        (striking chunk row 0 of its target slot). ``tokens.shape[1]`` is
        the only shape degree of freedom, so the engine compiles exactly two
        of these: width ``chunk_size`` and width 1.

        This is the *score* stage of propose→score→accept: the full per-row
        logits ``(ns, C, V)`` come back (f32) for the host acceptance stage
        — row ``c`` of a speculating slot is the target distribution its
        draft row ``c`` was proposed against — alongside the in-jit sampled
        ``next_tokens`` (each slot's logits at ``q_len - 1``), which
        non-speculating slots commit directly."""
        cfg = self.model.cfg
        L = cfg.num_layers
        ns = self.n_slots
        chunk = tokens.shape[1]
        grp = cfg.attn.num_heads // cfg.attn.num_kv_heads
        desc = paged_fault_descriptor(faults, grp, chunk=chunk)
        cache = {"attn": PagedKVCache(
            k=state.k, v=state.v, kc1=state.kc1, kc2=state.kc2,
            vc1=state.vc1, vc2=state.vc2,
            bt=jnp.broadcast_to(bt[None], (L,) + bt.shape),
            pos=jnp.broadcast_to(pos[None], (L,) + pos.shape),
            q_len=jnp.broadcast_to(q_lens[None], (L, ns)),
            bad=jnp.zeros((L, ns, self.max_blocks), jnp.int32))}
        logits, rep, new_cache = self.model.score(
            params, tokens, cache, fault=desc)
        nc = new_cache["attn"]
        bad = jnp.any(nc.bad > 0, axis=0)                  # (ns, mb)
        new_state = PagedKVState(k=nc.k, v=nc.v, kc1=nc.kc1, kc2=nc.kc2,
                                 vc1=nc.vc1, vc2=nc.vc2)
        idx = jnp.clip(q_lens - 1, 0, chunk - 1)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]

        def key_of(seed, rid, counter):
            return jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)

        keys = jax.vmap(key_of)(seeds, rids, counters)
        next_tokens = sample_tokens(last, temperature=temps, top_k=topks,
                                    keys=keys)
        # the full per-row plane only leaves the program when an acceptance
        # stage will read it — with speculation off (trace-static) the
        # non-speculative hot path pays nothing for the generalization
        logits_out = logits.astype(jnp.float32) if self._proposer is not None \
            else jnp.zeros((0,), jnp.float32)
        return logits_out, next_tokens, rep, bad, new_state

    def _score_fn(self, params, tokens, state, bt, pos, q_lens, faults,
                  temps, topks, seeds, rids, counters, verify_sel):
        """Multi-token batched scoring step on the gather backend — the
        *score* stage of propose→score→accept for ``kernel="gather"``.

        Like :meth:`_decode_fn` but each slot feeds ``q_lens[slot]`` chunk
        rows (1 = plain decode riding along, more = a pending token plus
        draft rows; 0 = idle): gather-by-block-table with read-time checksum
        verify, a vmapped multi-token EFTA extend per slot (causal within
        the chunk, so row ``c`` conditions on rows ``< c`` exactly as
        sequential decoding would), every valid row's K/V scattered back
        into its block with the touched blocks' checksums regenerated, and
        the FULL per-row logits returned for host acceptance. Padding rows
        past ``q_len`` write to the null block and are causally invisible
        to valid rows. The in-jit ``next_tokens`` (row ``q_len - 1``) serve
        the non-speculating slots.

        One fixed width (``chunk_size``) keeps this a single compiled
        program; the engine only routes through it on steps where some slot
        actually speculates, so the K = 0 path stays byte-for-byte the
        PR-4 width-1 decode."""
        cfg = self.model.cfg
        a = cfg.attn
        L, ns, bs = cfg.num_layers, self.n_slots, self.block_size
        mb = self.max_blocks
        C = tokens.shape[1]
        kg, vg, bad = self._verify_gathered(state, bt, verify_sel)
        czero = jnp.zeros((L, ns, a.num_kv_heads, 1, a.head_dim), kg.dtype)
        cache = {"attn": KVCache(
            k=kg, v=vg, pos=jnp.broadcast_to(pos[None], (L, ns)),
            ck=czero, cv=czero)}
        axes = jax.tree.map(lambda _: 1, cache)

        def one(toks, row, f):
            logits, rep, new_row = self.model.score(
                params, toks[None], add_unit_batch(row), fault=f)
            return logits[0], rep, drop_unit_batch(new_row)

        logits, rep, new_cache = jax.vmap(
            one, in_axes=(0, axes, 0), out_axes=(0, 0, axes))(
                tokens, cache, faults)                      # (ns, C, V)

        # scatter the chunk's appended rows back into their blocks (padding
        # rows divert to the null scratch block), then regenerate exactly
        # the touched blocks' checksums — mirroring the fused append path
        node = new_cache["attn"]
        c_idx = jnp.arange(C, dtype=jnp.int32)
        p_abs = pos[:, None] + c_idx[None, :]               # (ns, C)
        valid = c_idx[None, :] < q_lens[:, None]
        p_clip = jnp.clip(p_abs, 0, self.cache_len - 1)
        take = p_clip[None, :, None, :, None]
        row_k = jnp.take_along_axis(node.k, take, axis=3)   # (L,ns,Hkv,C,hd)
        row_v = jnp.take_along_axis(node.v, take, axis=3)
        jrow = jnp.clip(p_abs // bs, 0, mb - 1)
        tgt_rows = jnp.where(valid, jnp.take_along_axis(bt, jrow, axis=1), 0)
        offs = jnp.where(valid, p_abs % bs, 0)
        vals_k = row_k.transpose(1, 3, 0, 2, 4)             # (ns,C,L,Hkv,hd)
        vals_v = row_v.transpose(1, 3, 0, 2, 4)
        new_k = state.k.at[:, tgt_rows, :, offs, :].set(vals_k)
        new_v = state.v.at[:, tgt_rows, :, offs, :].set(vals_v)
        nt = (C + bs - 2) // bs + 1
        j0 = pos // bs
        jt = j0[:, None] + jnp.arange(nt, dtype=jnp.int32)[None, :]
        last_j = (pos + jnp.maximum(q_lens, 1) - 1) // bs
        touched = (jt <= last_j[:, None]) & (q_lens[:, None] > 0)
        tid = jnp.where(
            touched, jnp.take_along_axis(bt, jnp.clip(jt, 0, mb - 1),
                                         axis=1), 0)
        ck = cks.encode_kv(new_k[:, tid], self.check_stride)
        cv = cks.encode_kv(new_v[:, tid], self.check_stride)
        new_state = PagedKVState(
            k=new_k, v=new_v,
            kc1=state.kc1.at[:, tid].set(ck.c1),
            kc2=state.kc2.at[:, tid].set(ck.c2),
            vc1=state.vc1.at[:, tid].set(cv.c1),
            vc2=state.vc2.at[:, tid].set(cv.c2))

        idx = jnp.clip(q_lens - 1, 0, C - 1)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]

        def key_of(seed, rid, counter):
            return jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)

        keys = jax.vmap(key_of)(seeds, rids, counters)
        next_tokens = sample_tokens(last, temperature=temps, top_k=topks,
                                    keys=keys)
        return (logits.astype(jnp.float32), next_tokens, rep, bad,
                new_state)

    def _rollback_fn(self, state, bt, keep_pos, old_pos):
        """Jitted fault-tolerant KV rollback (one program for every
        acceptance outcome — ``max_span`` is the static chunk width). See
        :func:`repro.models.attention.paged_rollback`."""
        k, v, kc1, kc2, vc1, vc2, bad = paged_rollback(
            state.k, state.v, state.kc1, state.kc2, state.vc1, state.vc2,
            bt, keep_pos, old_pos, check_stride=self.check_stride,
            threshold=self.check_threshold, max_span=self.chunk_size)
        return PagedKVState(k, v, kc1, kc2, vc1, vc2), bad

    def _gather_ctx_fn(self, state, bids, n_ctx):
        """Materialize a batch-1 contiguous context cache from ``bids`` (mb,)
        holding ``n_ctx`` tokens, verifying the blocks read."""
        cfg = self.model.cfg
        a = cfg.attn
        L = cfg.num_layers
        kg, vg, bad = self._verify_gathered(state, bids)
        in_ctx = jnp.arange(self.max_blocks) * self.block_size < n_ctx
        bad = bad & in_ctx
        kg = kg[:, None]                        # (L, 1, Hkv, cache_len, hd)
        vg = vg[:, None]
        czero = jnp.zeros((L, 1, a.num_kv_heads, 1, a.head_dim), kg.dtype)
        row = {"attn": KVCache(
            k=kg, v=vg, pos=jnp.full((L,), n_ctx, jnp.int32),
            ck=czero, cv=czero)}
        return row, bad

    def _extend_fn(self, params, tokens, row_cache, length, fault):
        return self.model.extend(params, tokens, row_cache,
                                 lengths=length, fault=fault)

    def _scatter_fn(self, state, row_cache, bids, length):
        """Write a batch-1 cache's rows into pool blocks. ``bids`` (mb,)
        names the destination of each block-sized row group (null entries
        discard that group); rows at positions >= ``length`` are zeroed, so a
        partial tail block is stored zero-padded and its checksums cover the
        padding deterministically."""
        mb, bs = self.max_blocks, self.block_size
        node = row_cache["attn"]
        mask = (jnp.arange(mb * bs) < length)[None, None, :, None]

        def blocks_of(x):      # (L, 1, Hkv, cache_len, hd) -> (L,mb,Hkv,bs,hd)
            x = jnp.where(mask, x[:, 0], 0.0)
            L, hkv, _, hd = x.shape
            return x.reshape(L, hkv, mb, bs, hd).transpose(0, 2, 1, 3, 4)

        kb = blocks_of(node.k)
        vb = blocks_of(node.v)
        ck = cks.encode_kv(kb, self.check_stride)
        cv = cks.encode_kv(vb, self.check_stride)
        return PagedKVState(
            k=state.k.at[:, bids].set(kb),
            v=state.v.at[:, bids].set(vb),
            kc1=state.kc1.at[:, bids].set(ck.c1),
            kc2=state.kc2.at[:, bids].set(ck.c2),
            vc1=state.vc1.at[:, bids].set(cv.c1),
            vc2=state.vc2.at[:, bids].set(cv.c2))

    def _scrub_fn(self, state, bids):
        """Background-scrub verify: re-fold the resident checksums of pool
        blocks ``bids`` (K,) straight off the pool (no gather, no attention)
        and flag mismatches. Null padding never flags."""
        s = self.check_stride
        thr = self.check_threshold
        bad_k, _ = cks.verify_block(
            state.k[:, bids],
            cks.Checksums(state.kc1[:, bids], state.kc2[:, bids]), s,
            threshold=thr)
        bad_v, _ = cks.verify_block(
            state.v[:, bids],
            cks.Checksums(state.vc1[:, bids], state.vc2[:, bids]), s,
            threshold=thr)
        return jnp.any(bad_k | bad_v, axis=(0, -1)) & (bids > NULL_BLOCK)

    def _copy_block_fn(self, state, src, dst):
        """Copy-on-write device copy: duplicate block ``src`` (data +
        checksums) into ``dst``."""
        return PagedKVState(*(arr.at[:, dst].set(arr[:, src])
                              for arr in state))

    def _flip_fn(self, state, layer, bid, head, row, col, bit, *, into):
        """Flip one bit of a resident pool block — an SEU striking KV state
        in HBM between decode steps."""
        arr = getattr(state, into)
        L, nb, hkv, bs, hd = arr.shape
        layer = jnp.clip(layer, 0, L - 1)
        bid = jnp.clip(bid, 0, nb - 1)
        head = jnp.clip(head, 0, hkv - 1)
        row = jnp.clip(row, 0, bs - 1)
        col = jnp.clip(col, 0, hd - 1)
        flat = (((layer * nb + bid) * hkv + head) * bs + row) * hd + col
        return state._replace(**{into: flip_bit_at(arr, flat, bit)})

    # -- resident-state fault injection -------------------------------------

    def inject_kv_fault(self, *, layer: int = 0, block: int = 1,
                        head: int = 0, row: int = 0, col: int = 0,
                        bit: int = 27, into: str = "k") -> None:
        """Flip one bit of pool block ``block`` (``into``: "k" | "v"). The
        corruption is persistent resident-state damage: it stays until the
        block checksums catch it at the next read and the engine re-prefills
        the block."""
        if into not in ("k", "v"):
            raise ValueError("into must be 'k' or 'v'")
        self.pool.state = self._flip(
            self.pool.state, jnp.int32(layer), jnp.int32(block),
            jnp.int32(head), jnp.int32(row), jnp.int32(col), jnp.int32(bit),
            into=into)

    # -- admission ----------------------------------------------------------

    def _resident_tokens(self, req: Request) -> np.ndarray:
        """Tokens whose KV this request keeps resident at steady state: the
        prompt plus all generated tokens except the pending one (written
        next step)."""
        gen = req.generated[:-1] if req.generated else []
        return np.concatenate([req.prompt,
                               np.asarray(gen, np.int32)]).astype(np.int32)

    def _feed_tokens(self, req: Request) -> np.ndarray:
        """Every token this request must feed through the model: the prompt
        plus all generated tokens (the last one is the pending decode
        input). The unified step consumes a chunk of these per iteration."""
        return np.concatenate([req.prompt, np.asarray(req.generated,
                                                      np.int32)
                               ]).astype(np.int32)

    def _pad_bids(self, bids: Sequence[int]) -> np.ndarray:
        out = np.zeros((self.max_blocks,), np.int32)
        out[:len(bids)] = bids
        return out

    def _try_admit(self, req: Request) -> Optional[int]:
        """Reserve a slot + KV blocks (prefix-cache hits first). All-or-
        nothing: on failure everything is rolled back and the request keeps
        its place at the head of the queue."""
        if self.pool.free_slots == 0:
            return None
        seq = self._resident_tokens(req)
        t_ctx = len(seq)
        resumed = req.num_generated > 0
        # a fresh prompt must compute >= 1 token to produce logits; a resumed
        # request already knows its pending token and may be fully cached
        max_hit = t_ctx // self.block_size if resumed \
            else (t_ctx - 1) // self.block_size
        hits = self.pool.prefix.match(seq, max_blocks=max_hit)
        for b in hits:                      # claim before alloc can evict
            self.pool.blocks.ref_inc(b)
        n_needed = -(-t_ctx // self.block_size) - len(hits)
        new_bids: List[int] = []
        for _ in range(n_needed):
            b = self.pool.blocks.alloc()
            if b is None:
                for nb in new_bids:
                    self.pool.blocks.ref_dec(nb)
                for h in hits:
                    self.pool.blocks.ref_dec(h)
                return None
            new_bids.append(b)
        slot = self.pool.alloc()
        req.block_ids = list(hits) + new_bids
        req.n_prefix_hit = len(hits)
        return slot

    def _release_request(self, req: Request) -> None:
        slot = req.slot
        for b in req.block_ids:
            self.pool.blocks.ref_dec(b)
        req.block_ids = []
        self._bt[slot] = 0
        self._pos[slot] = 0
        self._queue[slot] = []
        if self._proposer is not None:
            self._proposer.release(slot)
        self.pool.release(slot)

    def _admit(self, req: Request) -> None:
        if self.kernel == "fused":
            self._admit_unified(req)
        else:
            self._admit_gather(req)

    def _admit_unified(self, req: Request) -> None:
        """Admission on the unified backend reserves state only — no
        compute. The prompt suffix past the prefix hit goes on the slot's
        feed queue; the mixed batched step prefills it chunk by chunk
        (budgeted) alongside other slots' decodes, samples the first token
        the moment the queue drains, and from then on the queue holds
        exactly the pending decode token."""
        slot = req.slot
        t_hit = req.n_prefix_hit * self.block_size
        feed = self._feed_tokens(req)
        self._pos[slot] = t_hit
        self._bt[slot] = self._pad_bids(req.block_ids)
        self._queue[slot] = [int(t) for t in feed[t_hit:]]
        s = req.sampling
        self._temps[slot] = s.temperature
        self._topks[slot] = s.top_k
        self._seeds[slot] = s.seed
        self._rids[slot] = req.rid
        self._counters[slot] = req.num_generated
        req.admit_order = self._admit_seq
        self._admit_seq += 1
        self.stats.prefills += 1

    def _chunked_fill(self, row, toks: np.ndarray, start_pos: int,
                      det_acc, cor_acc) -> Tuple[Any, Any, int]:
        """Feed ``toks`` into a contiguous batch-1 row cache through the ONE
        fixed-width chunked extend program (chunks of exactly
        ``chunk_size`` tokens; only the final chunk is padded, and only a
        prompt running into ``cache_len`` narrows the width). Replaces the
        former power-of-two prompt buckets — one compiled program per
        bucket size — with a single program reused for prefill, prefix
        extension and block repair. Returns (last-chunk logits, row,
        retries)."""
        none = FaultSpec.none(1)
        C = self.chunk_size
        i, retries = 0, 0
        logits = None
        n = len(toks)
        while i < n:
            pos = start_pos + i
            w = min(C, self.cache_len - pos)
            fill = min(w, n - i)
            buf = np.zeros((1, w), np.int32)
            buf[0, :fill] = toks[i:i + fill]
            length = jnp.asarray([fill], jnp.int32)
            logits, rep, new_row = self._extend(
                self.params, jnp.asarray(buf), row, length, none)
            det_acc[:5] += np.asarray(rep.detected, np.int64).reshape(-1)[:5]
            cor_acc[:5] += np.asarray(rep.corrected, np.int64).reshape(-1)[:5]
            while self._needs_retry_rows(rep, rows=None) and \
                    retries < self.max_retries:
                retries += 1
                logits, rep, new_row = self._extend(
                    self.params, jnp.asarray(buf), row, length, none)
                det_acc[:5] += np.asarray(rep.detected).reshape(-1)[:5]
                cor_acc[:5] += np.asarray(rep.corrected).reshape(-1)[:5]
            row = new_row
            i += fill
        return logits, row, retries

    def _admit_gather(self, req: Request) -> None:
        seq = self._resident_tokens(req)
        t_ctx = len(seq)
        resumed = req.num_generated > 0
        n_hit = req.n_prefix_hit
        t_hit = n_hit * self.block_size
        slot = req.slot
        det_acc = np.zeros((6,), np.int64)
        cor_acc = np.zeros((6,), np.int64)
        retries = 0
        logits = None

        if t_hit == t_ctx:
            pass                            # resumed & fully cached: no math
        else:
            if n_hit == 0:
                row = self.model.init_cache(1, cache_len=self.cache_len)
            else:
                ctx_bids = jnp.asarray(self._pad_bids(req.block_ids[:n_hit]))
                while True:
                    row, bad = self._gather_ctx(self.pool.state, ctx_bids,
                                                jnp.int32(t_hit))
                    bad_idx = np.flatnonzero(np.asarray(bad))
                    if bad_idx.size == 0:
                        break
                    # a shared prefix block rotted in HBM: repair before use
                    det_acc[5] += bad_idx.size
                    cor_acc[5] += bad_idx.size
                    self.paged_stats.kv_detected_blocks += int(bad_idx.size)
                    self._repair_blocks(req, bad_idx, resident=seq)
            logits, row, retries = self._chunked_fill(
                row, seq[t_hit:], t_hit, det_acc, cor_acc)
            sc = [NULL_BLOCK] * n_hit + req.block_ids[n_hit:]
            self.pool.state = self._scatter(
                self.pool.state, row, jnp.asarray(self._pad_bids(sc)),
                jnp.int32(t_ctx))
            for wb in req.block_ids[n_hit:]:
                self.pool.blocks.note_write(wb)

        self.pool.prefix.insert(seq, req.block_ids)
        self.telemetry.observe_prefill(req.rid, det_acc, cor_acc,
                                       retries=retries)
        req.retries += retries
        req.admit_order = self._admit_seq
        self._admit_seq += 1
        self.stats.prefills += 1
        self.stats.retries += retries

        s = req.sampling
        if resumed:
            tok = req.generated[-1]
            self._counters[slot] = req.num_generated
        else:
            key = jax.random.fold_in(request_key(s, req.rid), 0)
            first = sample_tokens(
                logits.astype(jnp.float32),
                temperature=jnp.asarray([s.temperature], jnp.float32),
                top_k=jnp.asarray([s.top_k], jnp.int32), keys=key[None])
            tok = int(first[0])
            req.generated.append(tok)
            self._counters[slot] = 1
            self.stats.tokens += 1
        self._pending[slot] = tok
        self._temps[slot] = s.temperature
        self._topks[slot] = s.top_k
        self._seeds[slot] = s.seed
        self._rids[slot] = req.rid
        self._bt[slot] = self._pad_bids(req.block_ids)
        self._pos[slot] = t_ctx

    # -- pressure: tail blocks, COW, preemption -----------------------------

    def _preempt_for_blocks(self, needy: Request) -> bool:
        """Preempt the youngest other running request to free blocks."""
        victims = [r for r in self.scheduler.active_rows()
                   if r is not needy and not r.is_done()]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.admit_order)
        slot = victim.slot
        self.scheduler.preempt(victim)
        self._release_request(victim)
        victim.slot = None
        self.paged_stats.preemptions += 1
        return True

    def _alloc_block_or_preempt(self, req: Request) -> int:
        while True:
            b = self.pool.blocks.alloc()
            if b is not None:
                return b
            if not self._preempt_for_blocks(req):
                raise RuntimeError(
                    "paged KV pool exhausted: a single request needs more "
                    "blocks than the pool holds; raise num_blocks")

    def _ensure_capacity(self, req: Request, n_new: int) -> None:
        """Back the next ``n_new`` KV rows of ``req`` (positions ``pos ..
        pos + n_new - 1``) with writable private blocks: allocate fresh tail
        blocks, copy-on-write-split shared ones (a registered or
        prefix-shared block must not observe the append), preempting the
        youngest other request under pool pressure."""
        slot = req.slot
        pos = int(self._pos[slot])
        bs = self.block_size
        for bi in range(pos // bs, (pos + max(n_new, 1) - 1) // bs + 1):
            if req.slot is None:
                return              # preempted (cannot happen for req itself)
            if bi >= len(req.block_ids):
                b = self._alloc_block_or_preempt(req)
                req.block_ids.append(b)
                self._bt[slot, bi] = b
            else:
                tail = req.block_ids[bi]
                if self.pool.blocks.is_shared(tail):
                    wb, needs_copy = self.pool.blocks.cow(tail)
                    if wb is None:
                        wb = self._alloc_block_or_preempt(req)
                        self.pool.blocks.ref_dec(tail)
                        needs_copy = True
                    if needs_copy:
                        self.pool.state = self._copy_block(
                            self.pool.state, jnp.int32(tail), jnp.int32(wb))
                        self.pool.blocks.note_write(wb)
                    req.block_ids[bi] = wb
                    self._bt[slot, bi] = wb

    def _ensure_tail_blocks(self) -> None:
        """Before a gather decode step every active slot writes one KV row
        at its position — make sure a private tail block backs it."""
        for req in list(self.scheduler.active_rows()):
            if req.slot is None:
                continue        # preempted by an earlier request's alloc
            slot = req.slot
            if req.is_done():
                # finished at admission; decodes garbage until evicted next
                # iteration — point its writes at the null block
                self._bt[slot] = 0
                self._pos[slot] = 0
                continue
            self._ensure_capacity(req, 1)

    # -- prefix registration (prompt AND decode-filled blocks) --------------

    def _register_full_blocks(self, req: Request, old_pos: int,
                              new_pos: int) -> None:
        """Register every newly *completed* block of ``req`` in the
        token-hash-chain prefix cache. Beyond shared prompts, this covers
        decode-filled blocks: a later request replaying the same prompt +
        continuation prefix (n-best / self-consistency resampling) hits
        cache instead of re-prefilling. Full blocks are immutable — a
        subsequent append to a registered block copy-on-write-splits via
        the existing machinery."""
        bs = self.block_size
        if new_pos // bs <= old_pos // bs:
            return
        toks = self._feed_tokens(req)[:new_pos]
        self.pool.prefix.insert(toks, req.block_ids)

    # -- read-time verification policy --------------------------------------

    def _verify_selector(self):
        """Pick the table entries this gather decode attempt re-verifies.

        Returns ``(sel, folds, skips)``: ``sel`` is None for full coverage
        (the "always" policy), else an (n_slots, K) int32 selector (-1 =
        empty). Under the generation-stamped policy only blocks written
        since their last verified read need a fold — in steady-state decode
        that is one tail block per slot instead of the whole table, which is
        where the gather path's checksum overhead (the ~0.85x decode
        regression) goes. A step needing more than K folds per slot (e.g.
        right after an admission scattered a whole prompt) falls back to
        full coverage.
        """
        live = [r for r in self.scheduler.active_rows()
                if r.slot is not None and not r.is_done()]
        n_real = sum(len(r.block_ids) for r in live)
        if self.kv_verify == "always":
            return None, n_real, 0
        sel = np.full((self.n_slots, self._sel_width), -1, np.int32)
        need_total = 0
        for r in live:
            need = [j for j, bid in enumerate(r.block_ids)
                    if self.pool.blocks.needs_verify(bid)]
            if len(need) > self._sel_width:
                return self._sel_all, n_real, 0       # full-coverage fallback
            sel[r.slot, :len(need)] = need
            need_total += len(need)
        return sel, need_total, n_real - need_total

    # -- background scrub (bounds the stamped policy's deferred window) -----

    def _scrub_pass(self) -> None:
        """Re-fold the ``scrub_batch`` oldest-verified live blocks against
        their resident checksums — including blocks the stamped selector
        skips as verified-and-untouched, which is exactly where a deferred
        flip hides. A mismatch is repaired immediately through the normal
        block re-prefill path; clean blocks refresh their verification
        clock so the scrub cursor keeps rotating.

        Leftover batch capacity draws from the **parked prefix-cache
        blocks** (ref == 0, retained for future hits): they sit in no live
        table, so read-time verification never reaches them and a flip
        would otherwise wait for the next admission gather to surface. A
        corrupted parked block is discarded (prefix-cache entry forgotten,
        block freed) — detection-before-use repair for cache-only state:
        the next admission takes a clean miss and re-prefills."""
        live = {}
        for req in self.scheduler.active_rows():
            if req.slot is None or req.is_done():
                continue
            for j, bid in enumerate(req.block_ids):
                live.setdefault(bid, (req, j))
        order = sorted(live, key=self.pool.blocks.verified_at)
        batch = order[:self.scrub_batch]
        if len(batch) < self.scrub_batch:
            parked = sorted(self.pool.blocks.parked_blocks(),
                            key=self.pool.blocks.verified_at)
            batch = batch + parked[:self.scrub_batch - len(batch)]
        if not batch:
            return
        padded = batch + [NULL_BLOCK] * (self.scrub_batch - len(batch))
        bad = np.asarray(self._scrub(self.pool.state,
                                     jnp.asarray(padded, dtype=jnp.int32)))
        self.paged_stats.kv_scrubbed_blocks += len(batch)
        for bid, is_bad in zip(batch, bad[:len(batch)]):
            if bid in live:
                req, j = live[bid]
                if is_bad:
                    self.paged_stats.kv_detected_blocks += 1
                    six = np.zeros((6,), np.int64)
                    six[5] = 1
                    self.telemetry.observe_prefill(req.rid, six, six)
                    self._repair_blocks(req, [j])
                else:
                    self.pool.blocks.mark_verified(bid)
            else:                           # parked prefix-cache block
                if is_bad:
                    self.paged_stats.kv_detected_blocks += 1
                    self.telemetry.observe_scrub(1)
                    self.pool.blocks.discard_parked(bid)
                else:
                    self.pool.blocks.mark_verified(bid)

    # -- read-time repair ---------------------------------------------------

    def _repair_blocks(self, req: Request, bad_idx, *,
                       resident: Optional[np.ndarray] = None,
                       healed: Optional[set] = None) -> None:
        """Re-prefill the poisoned blocks of one request, left to right, so
        each repair runs against already-verified (or just-repaired) context.
        Shared blocks heal in place for every request mapping them (``healed``
        dedupes repairs of a shared block flagged from several slots). The
        fused backend routes every repair through the SAME unified chunked
        program the mixed batch runs; the gather backend through the same
        fixed-width extend as admission — either way repair never compiles
        anything new, even under pool pressure."""
        if self.kernel == "fused":
            self._repair_blocks_unified(req, bad_idx, resident=resident,
                                        healed=healed)
        else:
            self._repair_blocks_gather(req, bad_idx, resident=resident,
                                       healed=healed)

    def _repair_blocks_unified(self, req: Request, bad_idx, *,
                               resident: Optional[np.ndarray] = None,
                               healed: Optional[set] = None) -> None:
        slot = req.slot
        bs = self.block_size
        if resident is None:
            resident = self._feed_tokens(req)[:int(self._pos[slot])]
        for j in sorted(int(i) for i in bad_idx):
            start = j * bs
            n_fill = min(bs, len(resident) - start)
            if n_fill <= 0:
                continue
            if healed is not None:
                if req.block_ids[j] in healed:
                    continue
                healed.add(req.block_ids[j])
            # single-slot chunk with the position rewound to the block
            # start: the kernel recomputes exactly this block's rows against
            # the (verified) preceding context and the chunk scatter +
            # checksum refresh rewrites only block j. Other slots ride along
            # with q_len = 0 and are untouched.
            tokens = np.zeros((self.n_slots, self.chunk_size), np.int32)
            tokens[slot, :n_fill] = resident[start:start + n_fill]
            q_lens = np.zeros((self.n_slots,), np.int32)
            q_lens[slot] = n_fill
            pos_vec = self._pos.copy()
            pos_vec[slot] = start
            _, _, _, _, new_state = self._step_fused(
                self.params, jnp.asarray(tokens), self.pool.state,
                jnp.asarray(self._bt), jnp.asarray(pos_vec),
                jnp.asarray(q_lens), self._no_faults,
                jnp.asarray(self._temps), jnp.asarray(self._topks),
                jnp.asarray(self._seeds), jnp.asarray(self._rids),
                jnp.asarray(self._counters))
            self.pool.state = new_state
            self.pool.blocks.note_write(req.block_ids[j])
            self.paged_stats.kv_repaired_blocks += 1

    def _repair_blocks_gather(self, req: Request, bad_idx, *,
                              resident: Optional[np.ndarray] = None,
                              healed: Optional[set] = None) -> None:
        bs = self.block_size
        seq = self._resident_tokens(req) if resident is None else resident
        none = FaultSpec.none(1)
        for j in sorted(int(i) for i in bad_idx):
            start = j * bs
            n_fill = min(bs, len(seq) - start)
            if n_fill <= 0:
                continue
            if healed is not None:
                if req.block_ids[j] in healed:
                    continue
                healed.add(req.block_ids[j])
            ctx_bids = jnp.asarray(self._pad_bids(req.block_ids[:j]))
            row, _ = self._gather_ctx(self.pool.state, ctx_bids,
                                      jnp.int32(start))
            w = min(self.chunk_size, self.cache_len - start)
            toks = np.zeros((1, w), np.int32)
            toks[0, :n_fill] = seq[start:start + n_fill]
            _, _, new_row = self._extend(
                self.params, jnp.asarray(toks), row,
                jnp.asarray([n_fill], jnp.int32), none)
            sc = [NULL_BLOCK] * self.max_blocks
            sc[j] = req.block_ids[j]
            self.pool.state = self._scatter(
                self.pool.state, new_row, jnp.asarray(sc, dtype=jnp.int32),
                jnp.int32(start + n_fill))
            self.pool.blocks.note_write(req.block_ids[j])
            self.paged_stats.kv_repaired_blocks += 1

    # -- speculation: propose / accept / roll back --------------------------

    @property
    def acceptance_rate(self) -> float:
        """Fraction of scored draft tokens the target accepted."""
        ps = self.paged_stats
        return 0.0 if not ps.spec_proposed_tokens \
            else ps.spec_accepted_tokens / ps.spec_proposed_tokens

    def _spec_cap(self, req: Request) -> int:
        """Most draft rows this request may score this step: bounded by the
        configured draft length, the chunk width (the pending token takes
        one row), and the request's remaining token budget (a spec step
        commits at most K + 1 tokens, never past ``max_new_tokens``)."""
        return max(0, min(self.draft_len, self.chunk_size - 1,
                          req.max_new_tokens - req.num_generated - 1))

    def _propose_drafts(self, active_reqs: Sequence[Request],
                        draft_grants: Dict[int, int]
                        ) -> Dict[int, np.ndarray]:
        """Run the proposer for every slot granted draft budget. Returns
        slot -> draft tokens (slots with empty proposals are left out — the
        K = 0 degenerate path). Draft-pass EFTA telemetry (the draft
        model's own detections/retries) is folded into the per-request
        draft counters here."""
        spec: Dict[int, np.ndarray] = {}
        for r in active_reqs:
            kd = draft_grants.get(r.rid, 0)
            if kd <= 0 or r.slot is None:
                continue
            d = self._proposer.propose(r.slot, self._feed_tokens(r), kd)
            rep = self._proposer.drain_report()
            if rep is not None:
                det, cor, retries = rep
                six_d = np.concatenate([det, [0]]).astype(np.int64)
                six_c = np.concatenate([cor, [0]]).astype(np.int64)
                self.telemetry.observe_draft(r.rid, six_d, six_c,
                                             retries=retries)
            if len(d):
                spec[r.slot] = np.asarray(d, np.int32)
        return spec

    def _accept_slot(self, req: Request, rows: np.ndarray,
                     drafts: np.ndarray
                     ) -> Tuple[List[int], Optional[int]]:
        """Acceptance verdict for one slot's scored chunk. ``rows``:
        (k+1, V) target logits (row j scored draft j; row k feeds the bonus
        token). Returns ``(drafts_committed, bonus)`` — the accepted draft
        prefix (possibly EOS-truncated) and the follow-up token (``None``
        when an accepted EOS ends the request before the bonus row)."""
        s = req.sampling
        rng = None
        if s.temperature > 0.0:
            # per-(request, step) deterministic stream, independent of the
            # in-jit sampler's keys (greedy never consults it)
            rng = np.random.default_rng(
                (abs(int(s.seed)), int(req.rid), int(req.num_generated)))
        a, t_next = speculative_accept(
            rows, drafts, temperature=float(s.temperature),
            top_k=int(s.top_k), rng=rng)
        drafts_committed = [int(t) for t in drafts[:a]]
        bonus: Optional[int] = int(t_next)
        if req.eos_id is not None:
            for i, t in enumerate(drafts_committed):
                if t == req.eos_id:
                    drafts_committed = drafts_committed[:i + 1]
                    bonus = None
                    break
        return drafts_committed, bonus

    def _apply_rollback(self, rollback_plan: Dict[int, Tuple[int, int]],
                        by_slot: Dict[int, Request]) -> None:
        """Truncate the rejected draft rows of every speculating slot in one
        jitted pass (``kv_len`` truncation + tail-block checksum
        re-generation), with the anti-laundering guard: blocks that fail
        their PRE-rollback checksums are flagged, counted as site-6
        detections, and re-prefilled from committed tokens — corruption
        that struck between the scoring step's verify and this rollback is
        detected, never silently restamped into a consistent state."""
        if self._pre_rollback_hook is not None:
            self._pre_rollback_hook(self)
        keep = self._pos.copy()                 # already rewound to keep_pos
        oldp = self._pos.copy()
        for slot, (keep_pos, scored_pos) in rollback_plan.items():
            keep[slot] = keep_pos
            oldp[slot] = scored_pos
        if not (oldp > keep).any():
            return
        new_state, bad = self._rollback(
            self.pool.state, jnp.asarray(self._bt), jnp.asarray(keep),
            jnp.asarray(oldp))
        self.pool.state = new_state
        bs = self.block_size
        for slot, (keep_pos, scored_pos) in rollback_plan.items():
            if scored_pos <= keep_pos:
                continue
            req = by_slot[slot]
            self.paged_stats.spec_rolled_back_rows += scored_pos - keep_pos
            for bi in range(keep_pos // bs,
                            min((scored_pos - 1) // bs + 1,
                                len(req.block_ids))):
                self.pool.blocks.note_write(req.block_ids[bi])
        bad_np = np.asarray(bad)
        for slot in list(rollback_plan):
            idxs = np.flatnonzero(bad_np[slot])
            if idxs.size == 0:
                continue
            req = by_slot[slot]
            self.paged_stats.rollback_detected_blocks += int(idxs.size)
            self.paged_stats.kv_detected_blocks += int(idxs.size)
            six = np.zeros((6,), np.int64)
            six[5] = idxs.size
            self.telemetry.observe_prefill(req.rid, six, six)
            # blocks holding committed rows re-prefill from the committed
            # tokens (resident passed explicitly: after an accepted EOS
            # draft every generated token's KV row is resident, unlike the
            # non-speculative pending-token convention). A flagged block
            # wholly past the committed prefix needs no re-prefill — the
            # rollback just rewrote and restamped it and none of its rows
            # are reachable below kv_len — so the truncation IS its repair.
            keep_pos = int(self._pos[slot])
            resident = self._feed_tokens(req)[:keep_pos]
            trunc_only = sum(1 for j in idxs if j * bs >= keep_pos)
            self.paged_stats.kv_repaired_blocks += trunc_only
            self._repair_blocks(req, idxs, resident=resident)

    # -- stepping -----------------------------------------------------------

    def step(self, faults: Optional[FaultSpec] = None) -> List[Request]:
        """One engine iteration. EFTA in-compute SEUs behave exactly as in
        the ring engine; additionally every KV block read is checksum-
        verified, and a mismatch triggers block re-prefill + step retry
        before anything is committed. The fused backend runs the unified
        mixed prefill/decode batched step; the gather backend the
        single-token decode step (its prompts prefill at admission)."""
        if self.kernel == "fused":
            return self._step_unified(faults)
        return self._step_gather(faults)

    def _step_unified(self, faults: Optional[FaultSpec] = None
                      ) -> List[Request]:
        decision = self.scheduler.step(self._try_admit, self._release_request)
        for req in decision.admitted:
            self._admit(req)
        finished = list(decision.evicted)
        for r in self.scheduler.active_rows():
            if r.is_done() and r.slot is not None:
                # finished at admission; computes garbage until evicted next
                # iteration — park its writes on the null block
                self._bt[r.slot] = 0
                self._pos[r.slot] = 0
                self._queue[r.slot] = []
        active_reqs = [r for r in self.scheduler.active_rows()
                       if not r.is_done()]
        if not active_reqs:
            return finished

        # chunk plan: one token per request unconditionally (decodes never
        # starve), prompt surplus FCFS within the scheduler's chunk budget;
        # with speculation on, steady-state decodes additionally propose up
        # to draft_len candidate rows from the leftover budget (prompt
        # chunks rank first — speculation never starves admissions)
        demands = [(r, len(self._queue[r.slot])) for r in active_reqs]
        spec_tokens: Dict[int, np.ndarray] = {}
        if self._proposer is not None:
            wants = {r.rid: self._spec_cap(r) for r in active_reqs}
            grants, draft_grants = self.scheduler.plan_chunks(
                demands, self.chunk_size, draft_wants=wants)
            spec_tokens = self._propose_drafts(active_reqs, draft_grants)
        else:
            grants = self.scheduler.plan_chunks(demands, self.chunk_size)
        for r in list(active_reqs):
            need = grants[r.rid] + len(spec_tokens.get(r.slot, ()))
            if r.slot is not None and need > 0:
                self._ensure_capacity(r, need)
        active_reqs = [r for r in active_reqs
                       if r.slot is not None and not r.is_done()]
        spec_tokens = {s: d for s, d in spec_tokens.items()
                       if any(r.slot == s for r in active_reqs)}
        if not active_reqs:
            return finished
        active = [r.slot for r in active_reqs]
        by_slot = {r.slot: r for r in active_reqs}

        # pure-decode steps run the width-1 program; any prefill surplus or
        # draft row promotes the step to the chunk-width program (the only
        # two shapes this engine ever compiles — draft K pads to the chunk)
        chunk = self.chunk_size if (spec_tokens or any(
            grants[r.rid] > 1 for r in active_reqs)) else 1
        tokens = np.zeros((self.n_slots, chunk), np.int32)
        q_lens = np.zeros((self.n_slots,), np.int32)
        for r in active_reqs:
            g = grants[r.rid]
            tokens[r.slot, :g] = self._queue[r.slot][:g]
            d = spec_tokens.get(r.slot)
            if d is not None:
                tokens[r.slot, g:g + len(d)] = d
                g += len(d)
            q_lens[r.slot] = g

        if faults is None:
            faults = self._no_faults
        kv_det = np.zeros((self.n_slots,), np.int64)
        kv_cor = np.zeros((self.n_slots,), np.int64)
        efta_retries = 0
        kv_retries = 0
        attempt_faults = faults
        det_acc = np.zeros((self.n_slots, 5), np.int64)
        cor_acc = np.zeros((self.n_slots, 5), np.int64)
        redet_acc = np.zeros((self.n_slots, 5), np.int64)
        kv_redet = np.zeros((self.n_slots,), np.int64)
        seen_bad: set = set()
        tok_dev = jnp.asarray(tokens)
        qlen_dev = jnp.asarray(q_lens)
        while True:
            is_retry = (efta_retries + kv_retries) > 0
            logits, next_tokens, rep, bad, new_state = self._step_fused(
                self.params, tok_dev, self.pool.state,
                jnp.asarray(self._bt), jnp.asarray(self._pos), qlen_dev,
                attempt_faults, jnp.asarray(self._temps),
                jnp.asarray(self._topks), jnp.asarray(self._seeds),
                jnp.asarray(self._rids), jnp.asarray(self._counters))
            det_acc += np.asarray(rep.detected, np.int64)
            cor_acc += np.asarray(rep.corrected, np.int64)
            if is_retry:
                redet_acc += np.asarray(rep.detected, np.int64)
            bad_np = np.asarray(bad)
            kv_hit_slots = [s for s in active if bad_np[s].any()]
            if kv_hit_slots:
                # resident corruption: the attempt read poisoned KV — repair
                # the blocks, drop the attempt (nothing committed), retry.
                # KV retries have their own (>= 1) budget independent of the
                # EFTA one: committing an attempt derived from poisoned KV
                # would bake the corruption into the refreshed block
                # checksums and make it permanently undetectable.
                kv_det[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                if is_retry:
                    kv_redet[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                bad_bids = {by_slot[s].block_ids[j] for s in kv_hit_slots
                            for j in np.flatnonzero(bad_np[s])
                            if j < len(by_slot[s].block_ids)}
                self.paged_stats.kv_detected_blocks += \
                    len(bad_bids - seen_bad)
                seen_bad |= bad_bids
                healed: set = set()
                for s in kv_hit_slots:
                    idxs = np.flatnonzero(bad_np[s])
                    kv_cor[s] += idxs.size
                    self._repair_blocks(by_slot[s], idxs, healed=healed)
                if kv_retries < max(1, self.max_retries):
                    kv_retries += 1
                    attempt_faults = self._no_faults
                    continue
            if self._needs_retry_rows(rep, rows=active) and \
                    efta_retries < self.max_retries:
                efta_retries += 1
                attempt_faults = self._no_faults
                continue
            break
        retries = efta_retries + kv_retries

        if kv_hit_slots:
            # the FINAL attempt still read poisoned KV: see _step_gather —
            # commit nothing, keep repairs, escalate if it persists.
            per_request = {
                r.rid: (np.concatenate([det_acc[r.slot],
                                        kv_det[r.slot:r.slot + 1]]),
                        np.concatenate([cor_acc[r.slot],
                                        kv_cor[r.slot:r.slot + 1]]),
                        np.concatenate([redet_acc[r.slot],
                                        kv_redet[r.slot:r.slot + 1]]))
                for r in active_reqs}
            for r in active_reqs:
                r.retries += retries
            self.telemetry.observe_step(per_request, retries=retries)
            self.stats.retries += retries
            self._poisoned_steps += 1
            if self._poisoned_steps > 3:
                raise RuntimeError(
                    "resident KV corruption persists across block re-prefills "
                    "on consecutive steps — failing memory, not a transient "
                    "SEU; cordon this host and restart elsewhere")
            return finished

        # commit
        self._poisoned_steps = 0
        self.pool.state = new_state
        next_np = np.asarray(next_tokens)
        logits_np = np.asarray(logits) if spec_tokens else None
        per_request = {}
        rollback_plan: Dict[int, Tuple[int, int]] = {}
        bs = self.block_size
        for req in active_reqs:
            slot = req.slot
            g = int(q_lens[slot])
            old_pos = int(self._pos[slot])
            new_pos = old_pos + g
            req.retries += retries
            d = spec_tokens.get(slot)
            if d is not None:
                # accept: commit the longest valid draft prefix + the bonus/
                # resample token; rewind the slot past the rejected rows
                # (the KV rollback below truncates them on-device)
                k = len(d)
                committed_drafts, bonus = self._accept_slot(
                    req, logits_np[slot, :k + 1], d)
                a = len(committed_drafts)
                keep_pos = old_pos + 1 + a     # pending row + accepted rows
                for bi in range(old_pos // bs,
                                min((new_pos - 1) // bs + 1,
                                    len(req.block_ids))):
                    self.pool.blocks.note_write(req.block_ids[bi])
                req.generated.extend(committed_drafts)
                if bonus is not None:
                    req.generated.append(bonus)
                self._queue[slot] = [] if bonus is None else [bonus]
                self._counters[slot] = req.num_generated
                self._pos[slot] = keep_pos
                n_new = a + (0 if bonus is None else 1)
                self.stats.tokens += n_new
                self.paged_stats.spec_proposed_tokens += k
                self.paged_stats.spec_accepted_tokens += a
                self.telemetry.observe_draft(
                    req.rid, np.zeros(6, np.int64), np.zeros(6, np.int64),
                    proposed=k, accepted=a)
                if keep_pos < new_pos:
                    rollback_plan[slot] = (keep_pos, new_pos)
                self._register_full_blocks(req, old_pos, keep_pos)
            elif g:
                if g > 1:
                    self.paged_stats.chunked_prefill_tokens += g
                # the chunk rewrote these blocks: their generations move
                # (and the prefix cache learns any block it completed)
                for bi in range(old_pos // bs,
                                min((new_pos - 1) // bs + 1,
                                    len(req.block_ids))):
                    self.pool.blocks.note_write(req.block_ids[bi])
                del self._queue[slot][:g]
                self._pos[slot] = new_pos
                if not self._queue[slot]:
                    # queue drained: this chunk's last row produced the next
                    # token (first sample for a fresh prompt, the steady-
                    # state decode sample otherwise)
                    tok = int(next_np[slot])
                    req.generated.append(tok)
                    self._queue[slot] = [tok]
                    self._counters[slot] = req.num_generated
                    self.stats.tokens += 1
                self._register_full_blocks(req, old_pos, new_pos)
            per_request[req.rid] = (
                np.concatenate([det_acc[slot], kv_det[slot:slot + 1]]),
                np.concatenate([cor_acc[slot], kv_cor[slot:slot + 1]]),
                np.concatenate([redet_acc[slot], kv_redet[slot:slot + 1]]))
        if spec_tokens:
            self.paged_stats.spec_steps += 1
        if rollback_plan:
            self._apply_rollback(rollback_plan, by_slot)
        self.telemetry.observe_step(per_request, retries=retries)
        self.stats.steps += 1
        self.stats.retries += retries
        return finished

    def _step_gather(self, faults: Optional[FaultSpec] = None
                     ) -> List[Request]:
        decision = self.scheduler.step(self._try_admit, self._release_request)
        for req in decision.admitted:
            self._admit(req)
        finished = list(decision.evicted)
        self._ensure_tail_blocks()
        active_reqs = [r for r in self.scheduler.active_rows()
                       if not r.is_done()]
        if not active_reqs:
            return finished

        # speculation (gather): the chunk-wide scoring program writes C rows
        # into each slot's contiguous temp view, so it needs headroom
        # ``pos + C <= cache_len`` on every slot (a ring wrap in the temp
        # would clobber context rows); near the boundary the step falls back
        # to the K = 0 width-1 decode below.
        spec_tokens: Dict[int, np.ndarray] = {}
        if self._proposer is not None and all(
                int(self._pos[r.slot]) + self.chunk_size <= self.cache_len
                for r in active_reqs):
            wants = {r.rid: self._spec_cap(r) for r in active_reqs}
            _, draft_grants = self.scheduler.plan_chunks(
                [(r, 1) for r in active_reqs], self.chunk_size,
                draft_wants=wants)
            spec_tokens = self._propose_drafts(active_reqs, draft_grants)
            for r in list(active_reqs):
                d = spec_tokens.get(r.slot)
                if d is not None and r.slot is not None:
                    self._ensure_capacity(r, 1 + len(d))
            if spec_tokens:
                # capacity pressure may have preempted someone — refilter
                active_reqs = [r for r in self.scheduler.active_rows()
                               if not r.is_done() and r.slot is not None]
                spec_tokens = {s: d for s, d in spec_tokens.items()
                               if any(r.slot == s for r in active_reqs)}
                if not active_reqs:
                    return finished
        if spec_tokens:
            return self._step_gather_spec(faults, finished, active_reqs,
                                          spec_tokens)

        active = [r.slot for r in active_reqs]
        by_slot = {r.slot: r for r in active_reqs}

        if faults is None:
            faults = self._no_faults
        kv_det = np.zeros((self.n_slots,), np.int64)
        kv_cor = np.zeros((self.n_slots,), np.int64)
        efta_retries = 0
        kv_retries = 0
        attempt_faults = faults
        det_acc = np.zeros((self.n_slots, 5), np.int64)
        cor_acc = np.zeros((self.n_slots, 5), np.int64)
        redet_acc = np.zeros((self.n_slots, 5), np.int64)
        kv_redet = np.zeros((self.n_slots,), np.int64)
        seen_bad: set = set()
        while True:
            is_retry = (efta_retries + kv_retries) > 0
            sel, folds, skips = self._verify_selector()
            self.paged_stats.kv_verified_blocks += folds
            self.paged_stats.kv_verify_skips += skips
            args = (jnp.asarray(self._pending), self.pool.state,
                    jnp.asarray(self._bt), jnp.asarray(self._pos),
                    attempt_faults, jnp.asarray(self._temps),
                    jnp.asarray(self._topks), jnp.asarray(self._seeds),
                    jnp.asarray(self._rids), jnp.asarray(self._counters),
                    None if sel is None else jnp.asarray(sel))
            next_tokens, rep, bad, new_state = self._decode(self.params, *args)
            det_acc += np.asarray(rep.detected, np.int64)
            cor_acc += np.asarray(rep.corrected, np.int64)
            if is_retry:
                redet_acc += np.asarray(rep.detected, np.int64)
            bad_np = np.asarray(bad)
            kv_hit_slots = [s for s in active if bad_np[s].any()]
            if kv_hit_slots:
                # resident corruption: the attempt read poisoned KV — repair
                # the blocks, drop the attempt (nothing committed), retry.
                # KV retries have their own (>= 1) budget independent of the
                # EFTA one: committing an attempt derived from a poisoned
                # gather would bake the corruption into the tail block's
                # refreshed checksums and make it permanently undetectable.
                kv_det[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                if is_retry:
                    kv_redet[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                # pool-level stats count distinct *blocks*, once per step (a
                # shared prefix block flagged from several slots, or again on
                # a retry, is one corruption), so detected == repaired holds
                # under sharing; per-request telemetry above stays per-slot
                bad_bids = {by_slot[s].block_ids[j] for s in kv_hit_slots
                            for j in np.flatnonzero(bad_np[s])}
                self.paged_stats.kv_detected_blocks += \
                    len(bad_bids - seen_bad)
                seen_bad |= bad_bids
                healed: set = set()
                for s in kv_hit_slots:
                    idxs = np.flatnonzero(bad_np[s])
                    kv_cor[s] += idxs.size
                    self._repair_blocks(by_slot[s], idxs, healed=healed)
                if kv_retries < max(1, self.max_retries):
                    kv_retries += 1
                    attempt_faults = self._no_faults
                    continue
            if self._needs_retry_rows(rep, rows=active) and \
                    efta_retries < self.max_retries:
                efta_retries += 1
                attempt_faults = self._no_faults
                continue
            break
        retries = efta_retries + kv_retries

        if kv_hit_slots:
            # the FINAL attempt still read poisoned KV: a block that stays
            # corrupted through repeated re-prefills is being re-corrupted
            # underneath us (failing HBM, not a transient SEU). Committing
            # would bake the corruption into refreshed tail checksums and go
            # permanently silent — so commit nothing: repairs stay applied,
            # pending tokens are untouched, the next engine iteration
            # re-attempts, and the sustained detections drive the
            # FaultRateMonitor toward its "cordon" escalation.
            per_request = {
                r.rid: (np.concatenate([det_acc[r.slot],
                                        kv_det[r.slot:r.slot + 1]]),
                        np.concatenate([cor_acc[r.slot],
                                        kv_cor[r.slot:r.slot + 1]]),
                        np.concatenate([redet_acc[r.slot],
                                        kv_redet[r.slot:r.slot + 1]]))
                for r in active_reqs}
            for r in active_reqs:
                r.retries += retries
            self.telemetry.observe_step(per_request, retries=retries)
            self.stats.retries += retries
            self._poisoned_steps += 1
            if self._poisoned_steps > 3:
                raise RuntimeError(
                    "resident KV corruption persists across block re-prefills "
                    "on consecutive steps — failing memory, not a transient "
                    "SEU; cordon this host and restart elsewhere")
            return finished

        # commit
        self._poisoned_steps = 0
        self.pool.state = new_state
        if self.kv_verify == "stamped":
            # stamp what the committed attempt verified, BEFORE noting the
            # tail appends below (a stamp covers the pre-write generation)
            for req in active_reqs:
                entries = (range(len(req.block_ids)) if sel is None
                           or sel is self._sel_all
                           else [int(j) for j in sel[req.slot] if j >= 0])
                for j in entries:
                    if j < len(req.block_ids):
                        self.pool.blocks.mark_verified(req.block_ids[j])
        next_np = np.asarray(next_tokens)
        per_request = {}
        for req in active_reqs:
            slot = req.slot
            tok = int(next_np[slot])
            old_pos = int(self._pos[slot])
            req.generated.append(tok)
            req.retries += retries
            self._pending[slot] = tok
            self._counters[slot] += 1
            # the decode appended one KV row into the tail block: its
            # generation moves, so the stamp invalidates (re-verified next
            # read under the stamped policy)
            self.pool.blocks.note_write(
                req.block_ids[old_pos // self.block_size])
            self._pos[slot] += 1
            self._register_full_blocks(req, old_pos, old_pos + 1)
            per_request[req.rid] = (
                np.concatenate([det_acc[slot], kv_det[slot:slot + 1]]),
                np.concatenate([cor_acc[slot], kv_cor[slot:slot + 1]]),
                np.concatenate([redet_acc[slot], kv_redet[slot:slot + 1]]))
            self.stats.tokens += 1
        self.telemetry.observe_step(per_request, retries=retries)
        self.stats.steps += 1
        self.stats.retries += retries
        if self.kv_verify == "stamped" and self.scrub_interval and \
                self.stats.steps % self.scrub_interval == 0:
            self._scrub_pass()
        return finished

    def _step_gather_spec(self, faults, finished: List[Request],
                          active_reqs: List[Request],
                          spec_tokens: Dict[int, np.ndarray]
                          ) -> List[Request]:
        """Gather-backend propose→score→accept step: at least one slot
        scored draft rows, so the batch routes through the chunk-wide
        ``_score`` program (slots without drafts ride along with
        ``q_len = 1`` — their committed token is the in-jit sample of row 0,
        the same value the width-1 decode would produce). Mirrors
        :meth:`_step_gather`'s KV-repair/EFTA retry discipline, then runs
        the acceptance stage and the fault-tolerant KV rollback."""
        active = [r.slot for r in active_reqs]
        by_slot = {r.slot: r for r in active_reqs}
        C = self.chunk_size
        tokens = np.zeros((self.n_slots, C), np.int32)
        q_lens = np.zeros((self.n_slots,), np.int32)
        for r in active_reqs:
            slot = r.slot
            tokens[slot, 0] = self._pending[slot]
            g = 1
            d = spec_tokens.get(slot)
            if d is not None:
                tokens[slot, 1:1 + len(d)] = d
                g += len(d)
            q_lens[slot] = g

        if faults is None:
            faults = self._no_faults
        kv_det = np.zeros((self.n_slots,), np.int64)
        kv_cor = np.zeros((self.n_slots,), np.int64)
        efta_retries = 0
        kv_retries = 0
        attempt_faults = faults
        det_acc = np.zeros((self.n_slots, 5), np.int64)
        cor_acc = np.zeros((self.n_slots, 5), np.int64)
        redet_acc = np.zeros((self.n_slots, 5), np.int64)
        kv_redet = np.zeros((self.n_slots,), np.int64)
        seen_bad: set = set()
        tok_dev = jnp.asarray(tokens)
        qlen_dev = jnp.asarray(q_lens)
        while True:
            is_retry = (efta_retries + kv_retries) > 0
            sel, folds, skips = self._verify_selector()
            self.paged_stats.kv_verified_blocks += folds
            self.paged_stats.kv_verify_skips += skips
            logits, next_tokens, rep, bad, new_state = self._score(
                self.params, tok_dev, self.pool.state,
                jnp.asarray(self._bt), jnp.asarray(self._pos), qlen_dev,
                attempt_faults, jnp.asarray(self._temps),
                jnp.asarray(self._topks), jnp.asarray(self._seeds),
                jnp.asarray(self._rids), jnp.asarray(self._counters),
                None if sel is None else jnp.asarray(sel))
            det_acc += np.asarray(rep.detected, np.int64)
            cor_acc += np.asarray(rep.corrected, np.int64)
            if is_retry:
                redet_acc += np.asarray(rep.detected, np.int64)
            bad_np = np.asarray(bad)
            kv_hit_slots = [s for s in active if bad_np[s].any()]
            if kv_hit_slots:
                # same contract as _step_gather: repair, drop the attempt,
                # retry — never commit an attempt that read poisoned KV
                kv_det[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                if is_retry:
                    kv_redet[kv_hit_slots] += bad_np[kv_hit_slots].sum(-1)
                bad_bids = {by_slot[s].block_ids[j] for s in kv_hit_slots
                            for j in np.flatnonzero(bad_np[s])
                            if j < len(by_slot[s].block_ids)}
                self.paged_stats.kv_detected_blocks += \
                    len(bad_bids - seen_bad)
                seen_bad |= bad_bids
                healed: set = set()
                for s in kv_hit_slots:
                    idxs = np.flatnonzero(bad_np[s])
                    kv_cor[s] += idxs.size
                    self._repair_blocks(by_slot[s], idxs, healed=healed)
                if kv_retries < max(1, self.max_retries):
                    kv_retries += 1
                    attempt_faults = self._no_faults
                    continue
            if self._needs_retry_rows(rep, rows=active) and \
                    efta_retries < self.max_retries:
                efta_retries += 1
                attempt_faults = self._no_faults
                continue
            break
        retries = efta_retries + kv_retries

        if kv_hit_slots:
            # final attempt still read poisoned KV — commit nothing (see
            # _step_gather for the full rationale)
            per_request = {
                r.rid: (np.concatenate([det_acc[r.slot],
                                        kv_det[r.slot:r.slot + 1]]),
                        np.concatenate([cor_acc[r.slot],
                                        kv_cor[r.slot:r.slot + 1]]),
                        np.concatenate([redet_acc[r.slot],
                                        kv_redet[r.slot:r.slot + 1]]))
                for r in active_reqs}
            for r in active_reqs:
                r.retries += retries
            self.telemetry.observe_step(per_request, retries=retries)
            self.stats.retries += retries
            self._poisoned_steps += 1
            if self._poisoned_steps > 3:
                raise RuntimeError(
                    "resident KV corruption persists across block "
                    "re-prefills on consecutive steps — failing memory, not "
                    "a transient SEU; cordon this host and restart "
                    "elsewhere")
            return finished

        # commit
        self._poisoned_steps = 0
        self.pool.state = new_state
        if self.kv_verify == "stamped":
            for req in active_reqs:
                entries = (range(len(req.block_ids)) if sel is None
                           or sel is self._sel_all
                           else [int(j) for j in sel[req.slot] if j >= 0])
                for j in entries:
                    if j < len(req.block_ids):
                        self.pool.blocks.mark_verified(req.block_ids[j])
        next_np = np.asarray(next_tokens)
        logits_np = np.asarray(logits)
        per_request = {}
        rollback_plan: Dict[int, Tuple[int, int]] = {}
        bs = self.block_size
        for req in active_reqs:
            slot = req.slot
            old_pos = int(self._pos[slot])
            g = int(q_lens[slot])
            scored_pos = old_pos + g
            req.retries += retries
            d = spec_tokens.get(slot)
            if d is None:
                tok = int(next_np[slot])
                req.generated.append(tok)
                self._pending[slot] = tok
                self._counters[slot] += 1
                self.pool.blocks.note_write(
                    req.block_ids[old_pos // bs])
                self._pos[slot] = old_pos + 1
                self._register_full_blocks(req, old_pos, old_pos + 1)
                self.stats.tokens += 1
            else:
                k = len(d)
                committed_drafts, bonus = self._accept_slot(
                    req, logits_np[slot, :k + 1], d)
                a = len(committed_drafts)
                keep_pos = old_pos + 1 + a
                for bi in range(old_pos // bs,
                                min((scored_pos - 1) // bs + 1,
                                    len(req.block_ids))):
                    self.pool.blocks.note_write(req.block_ids[bi])
                req.generated.extend(committed_drafts)
                if bonus is not None:
                    req.generated.append(bonus)
                    self._pending[slot] = bonus
                self._counters[slot] = req.num_generated
                self._pos[slot] = keep_pos
                self.stats.tokens += a + (0 if bonus is None else 1)
                self.paged_stats.spec_proposed_tokens += k
                self.paged_stats.spec_accepted_tokens += a
                self.telemetry.observe_draft(
                    req.rid, np.zeros(6, np.int64), np.zeros(6, np.int64),
                    proposed=k, accepted=a)
                if keep_pos < scored_pos:
                    rollback_plan[slot] = (keep_pos, scored_pos)
                self._register_full_blocks(req, old_pos, keep_pos)
            per_request[req.rid] = (
                np.concatenate([det_acc[slot], kv_det[slot:slot + 1]]),
                np.concatenate([cor_acc[slot], kv_cor[slot:slot + 1]]),
                np.concatenate([redet_acc[slot], kv_redet[slot:slot + 1]]))
        self.paged_stats.spec_steps += 1
        if rollback_plan:
            self._apply_rollback(rollback_plan, by_slot)
        self.telemetry.observe_step(per_request, retries=retries)
        self.stats.steps += 1
        self.stats.retries += retries
        if self.kv_verify == "stamped" and self.scrub_interval and \
                self.stats.steps % self.scrub_interval == 0:
            self._scrub_pass()
        return finished
