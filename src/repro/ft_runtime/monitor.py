"""Straggler and fault monitoring for the training loop and serve engine.

On a real pod this wraps per-host heartbeats; the detection logic (which is
what we can exercise here) is host-agnostic: robust step-time outliers via
median + MAD, plus an EFTA fault-rate monitor that escalates when the
attention layer reports a sustained detection rate (a symptom of a failing
chip rather than transient SEUs — the launcher should then cordon the host
and trigger an elastic restart from the last checkpoint).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    step_time: float
    median: float
    threshold: float


class StragglerMonitor:
    """Flags steps slower than median + k*MAD over a sliding window."""

    def __init__(self, window: int = 50, k: float = 6.0, warmup: int = 5):
        self.times: Deque[float] = collections.deque(maxlen=window)
        self.k = k
        self.warmup = warmup
        self._t0: Optional[float] = None
        self.flagged = 0

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self) -> StragglerVerdict:
        dt = time.perf_counter() - self._t0
        verdict = self.observe(dt)
        return verdict

    def observe(self, dt: float) -> StragglerVerdict:
        if len(self.times) < self.warmup:
            self.times.append(dt)
            return StragglerVerdict(False, dt, dt, float("inf"))
        ts = sorted(self.times)
        med = ts[len(ts) // 2]
        mad = sorted(abs(t - med) for t in ts)[len(ts) // 2]
        thr = med + self.k * max(mad, 0.05 * med)
        is_slow = dt > thr
        self.times.append(dt)
        if is_slow:
            self.flagged += 1
        return StragglerVerdict(is_slow, dt, med, thr)


N_FAULT_SITES = 6
SITE_LABELS = ("gemm1", "exp", "rowmax", "rowsum", "gemm2", "kv")


@dataclasses.dataclass
class RequestFaultStats:
    """Per-request fault telemetry aggregated over every decode step the
    request participated in. Site layout extends FTReport's 5-vector with a
    6th memory site: [gemm1, exp, rowmax, rowsum, gemm2, kv] — ``kv`` counts
    resident KV-block checksum mismatches caught at gather time by the paged
    cache (detected) and blocks healed by re-prefill (corrected). Engines
    that predate the paged cache report 5-vectors; the kv slot stays zero."""

    steps: int = 0
    # ``kv`` is fed by whichever verification caught the flip: the gather
    # backend's fold over gathered blocks, the fused kernel's in-loop verify
    # (report-tile word 6), the append-time tail check, or the speculative
    # rollback's pre-restamp guard — all share one fold/threshold definition
    # in ``repro.core.checksum``.
    detected: list = dataclasses.field(
        default_factory=lambda: [0] * N_FAULT_SITES)
    corrected: list = dataclasses.field(
        default_factory=lambda: [0] * N_FAULT_SITES)
    retries: int = 0
    # ``detected`` aggregates across every attempt of a step (a detection on
    # the first attempt AND on its retry counts twice). ``redetected``
    # splits out the retry attempts' detections, so campaign assertions can
    # distinguish "detected once, then retried clean" (detected == 1,
    # retries == 1, redetected == 0) from "detected twice" (redetected > 0
    # — the fault survived or restruck the re-execution).
    redetected: list = dataclasses.field(
        default_factory=lambda: [0] * N_FAULT_SITES)
    # speculative decoding: the *draft* pass is EFTA-protected too — its
    # detections/corrections are tracked separately from the target pass
    # (the ``detected``/``corrected`` vectors above), so a campaign can
    # attribute a strike to the pass it hit.
    draft_detected: list = dataclasses.field(
        default_factory=lambda: [0] * N_FAULT_SITES)
    draft_corrected: list = dataclasses.field(
        default_factory=lambda: [0] * N_FAULT_SITES)
    draft_retries: int = 0
    # acceptance telemetry: drafts this request scored vs drafts committed
    draft_proposed: int = 0
    draft_accepted: int = 0

    @property
    def total_detected(self) -> int:
        return sum(self.detected)

    @property
    def total_corrected(self) -> int:
        return sum(self.corrected)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of this request's scored draft tokens that the target
        accepted (0.0 when the request never speculated)."""
        return 0.0 if not self.draft_proposed \
            else self.draft_accepted / self.draft_proposed

    @property
    def detection_rate(self) -> float:
        """Fraction of this request's steps that saw >= 1 detection."""
        return 0.0 if not self.steps else self._steps_with_detection / self.steps

    _steps_with_detection: int = 0


def _pad_sites(v) -> list:
    """Normalize a 5- or 6-vector of per-site counts to N_FAULT_SITES."""
    v = [int(x) for x in v]
    return v + [0] * (N_FAULT_SITES - len(v))


class ServeFaultTelemetry:
    """Aggregates per-request and per-step FTReports for the serve engine.

    The engine calls ``observe_step`` once per *committed* decode step with
    the (rid -> (detected[5], corrected[5])) mapping of the rows that were
    active, plus how many retries the step took before committing. Feeds the
    same ``FaultRateMonitor`` escalation logic used by the training loop, so
    sustained detections (failing chip, not transient SEUs) surface as a
    "cordon" status for the launcher.
    """

    def __init__(self, monitor: Optional["FaultRateMonitor"] = None):
        self.requests: dict = {}
        self.step_log: list = []
        self.monitor = monitor or FaultRateMonitor()
        self.status = "ok"

    def _stats(self, rid: int) -> RequestFaultStats:
        return self.requests.setdefault(rid, RequestFaultStats())

    def observe_step(self, per_request: dict, *, retries: int = 0) -> str:
        step_detected = 0
        for rid, entry in per_request.items():
            det, cor = entry[0], entry[1]
            redet = entry[2] if len(entry) > 2 else None
            st = self._stats(rid)
            st.steps += 1
            st.retries += retries
            det = _pad_sites(det)
            cor = _pad_sites(cor)
            st.detected = [a + b for a, b in zip(st.detected, det)]
            st.corrected = [a + b for a, b in zip(st.corrected, cor)]
            if redet is not None:
                redet = _pad_sites(redet)
                st.redetected = [a + b for a, b in zip(st.redetected, redet)]
            if sum(det):
                st._steps_with_detection += 1
            step_detected += sum(det)
        self.step_log.append({"requests": len(per_request),
                              "detected": step_detected,
                              "retries": retries})
        self.status = self.monitor.observe(step_detected)
        return self.status

    def observe_draft(self, rid: int, det, cor, *, retries: int = 0,
                      proposed: int = 0, accepted: int = 0) -> str:
        """Record one request's *draft-pass* activity: the EFTA report of
        its draft-model forward (if any) plus the propose/accept tally of
        the step. Draft detections feed the same sustained-fault escalation
        as target-pass detections — a failing chip corrupts both."""
        st = self._stats(rid)
        det = _pad_sites(det)
        cor = _pad_sites(cor)
        st.draft_detected = [a + b for a, b in zip(st.draft_detected, det)]
        st.draft_corrected = [a + b for a, b in zip(st.draft_corrected, cor)]
        st.draft_retries += retries
        st.draft_proposed += proposed
        st.draft_accepted += accepted
        if sum(det) or retries:
            self.step_log.append({"requests": 1, "detected": sum(det),
                                  "retries": retries, "draft": True})
            self.status = self.monitor.observe(sum(det))
        return self.status

    def observe_scrub(self, detected: int) -> str:
        """Record a background-scrub detection with no owning request (a
        parked prefix-cache block rotted while unmapped). Counts toward the
        step log and the sustained-fault escalation like any other
        resident-state detection."""
        self.step_log.append({"requests": 0, "detected": int(detected),
                              "retries": 0, "scrub": True})
        self.status = self.monitor.observe(int(detected))
        return self.status

    def observe_prefill(self, rid: int, det, cor, *, retries: int = 0) -> str:
        st = self._stats(rid)
        det = _pad_sites(det)
        cor = _pad_sites(cor)
        st.detected = [a + b for a, b in zip(st.detected, det)]
        st.corrected = [a + b for a, b in zip(st.corrected, cor)]
        st.retries += retries
        # prefill detections count toward the step log and the sustained-
        # fault escalation just like decode steps: a failing chip corrupts
        # prefills too, and summary() must not under-report them
        self.step_log.append({"requests": 1, "detected": sum(det),
                              "retries": retries, "prefill": True})
        self.status = self.monitor.observe(sum(det))
        return self.status

    def summary(self) -> dict:
        steps = len(self.step_log)
        return {
            "steps": steps,
            "requests": len(self.requests),
            "detected": sum(s["detected"] for s in self.step_log),
            "retries": sum(s["retries"] for s in self.step_log),
            "status": self.status,
        }


class FaultRateMonitor:
    """Escalates when EFTA detections persist (suspect bad hardware)."""

    def __init__(self, window: int = 100, sustained_threshold: float = 0.2):
        self.history: Deque[int] = collections.deque(maxlen=window)
        self.sustained_threshold = sustained_threshold

    def observe(self, detected_this_step: int) -> str:
        self.history.append(int(detected_this_step))
        if not self.history:
            return "ok"
        rate = sum(1 for d in self.history if d > 0) / len(self.history)
        if len(self.history) >= 20 and rate >= self.sustained_threshold:
            return "cordon"      # sustained faults: cordon host, elastic restart
        if detected_this_step > 0:
            return "corrected"   # transient SEU handled in-kernel by EFTA
        return "ok"
