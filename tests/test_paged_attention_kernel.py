"""Parity + fault harness for the fused block-table EFTA paged-attention
kernel (``repro.kernels.efta_paged``).

The contract under test: for any block-table layout (permuted / fragmented),
any ragged per-request length, and any GQA head ratio, the fused kernel is
numerically interchangeable with the contiguous path —

    fused(bt)  ==  EFTA(gather_block_kv(pool, bt))  ==  reference softmax

with zero false-positive detections on clean pools; a resident pool bit flip
is flagged at the exact (request, table-slot) it occupies by the in-loop
verify (report-tile site 6, ``kv``); and in-compute SEUs at the five paper
sites behave exactly as in the contiguous EFTA kernel (corrected in
``correct`` mode, flagged in ``detect`` mode).
"""
import sys

import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _propcheck import given, settings, st  # noqa: E402


def _make_case(seed, *, B, mb, bs, hkv, grp, hd, cs, fragment=True,
               stale_scale=1.0):
    """Random pool + fragmented tables + ragged lengths. Pool rows past each
    request's valid prefix hold *stale* data (recycled-block model) scaled by
    ``stale_scale`` — the kernel must mask them out, not read zeros."""
    import jax.numpy as jnp
    from repro.core import checksum as cks

    rng = np.random.default_rng(seed)
    per_req = [int(rng.integers(1, mb * bs + 1)) for _ in range(B)]
    n_real = sum(-(-t // bs) for t in per_req)
    nb = n_real + 3                     # headroom: unmapped blocks stay stale
    ids = np.arange(1, nb + 1)
    if fragment:
        rng.shuffle(ids)
    bt = np.zeros((B, mb), np.int32)
    used = 0
    for i, t in enumerate(per_req):
        n = -(-t // bs)
        bt[i, :n] = ids[used:used + n]
        used += n
    pool_k = (rng.standard_normal((nb + 1, hkv, bs, hd)) * stale_scale
              ).astype(np.float32)
    pool_v = (rng.standard_normal((nb + 1, hkv, bs, hd)) * stale_scale
              ).astype(np.float32)
    if stale_scale != 1.0:
        # valid prefixes at unit scale; only rows past kv_len stay loud
        for i, t in enumerate(per_req):
            for j in range(-(-t // bs)):
                fill = min(bs, t - j * bs)
                for p in (pool_k, pool_v):
                    p[bt[i, j], :, :fill, :] = rng.standard_normal(
                        (hkv, fill, hd)).astype(np.float32)
    kc = cks.encode_kv(jnp.asarray(pool_k), cs)
    vc = cks.encode_kv(jnp.asarray(pool_v), cs)
    q = rng.standard_normal((B, hkv * grp, hd)).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v), kc, vc,
            jnp.asarray(bt), jnp.asarray(per_req, jnp.int32))


def _oracles(q, pool_k, pool_v, bt, kv_lens, *, cfg):
    """Per-request contiguous oracles: gather + pure-JAX EFTA, and the naive
    reference."""
    import numpy as np
    from repro.core.efta import efta_attention, reference_attention
    from repro.kernels.ops import gather_block_kv

    outs, refs = [], []
    for i in range(q.shape[0]):
        _, kg = gather_block_kv(pool_k[None], bt[i])
        _, vg = gather_block_kv(pool_v[None], bt[i])
        qi = q[i][None, :, None, :]
        o, rep = efta_attention(qi, kg, vg, cfg=cfg, kv_len=int(kv_lens[i]))
        assert int(np.sum(np.asarray(rep.detected))) == 0, \
            "oracle EFTA false positive"
        outs.append(np.asarray(o)[0, :, 0, :])
        refs.append(np.asarray(reference_attention(
            qi, kg, vg, kv_len=int(kv_lens[i])))[0, :, 0, :])
    return np.stack(outs), np.stack(refs)


@pytest.fixture(scope="module")
def std_case():
    """One standard shape (GQA 2:1, 3 fragmented tables, ragged lengths)
    with its jitted kernel — compiled once, shared by the quick tests."""
    import functools
    import jax
    from repro.core.efta import EFTAConfig
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    B, mb, bs, hkv, grp, hd, cs = 3, 3, 16, 2, 2, 16, 8
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=bs)
    case = _make_case(7, B=B, mb=mb, bs=bs, hkv=hkv, grp=grp, hd=hd, cs=cs)
    fn = jax.jit(functools.partial(efta_paged_attention_pallas, cfg=cfg,
                                   interpret=True))
    fn_fault = jax.jit(lambda *a, fault: efta_paged_attention_pallas(
        *a, cfg=cfg, fault=fault, interpret=True))
    return case, cfg, fn, fn_fault


@pytest.mark.quick
def test_fused_matches_gather_efta_and_reference(std_case):
    (q, pk, pv, kc, vc, bt, lens), cfg, fn, _ = std_case
    rep = fn(q, pk, pv, kc, vc, bt, lens)
    efta_out, ref_out = _oracles(q, pk, pv, bt, lens, cfg=cfg)
    got = np.asarray(rep.out)
    # same KV blocking + same f32 accumulation order as the pure-JAX scan
    np.testing.assert_allclose(got, efta_out, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(got, ref_out, atol=1e-4, rtol=1e-4)
    assert np.asarray(rep.detected).sum() == 0      # no false positives
    assert not np.asarray(rep.bad_blocks).any()


@pytest.mark.quick
def test_resident_flip_flagged_at_exact_block(std_case):
    """A pool SEU between steps: the in-loop verify must flag exactly the
    (request, table-slot) holding the flipped block — nothing else — and
    count it at report site 6 (kv)."""
    import jax.numpy as jnp
    from repro.core.fault import flip_bit_at

    (q, pk, pv, kc, vc, bt, lens), cfg, fn, _ = std_case
    rng = np.random.default_rng(3)
    bt_np, lens_np = np.asarray(bt), np.asarray(lens)
    hkv, bs, hd = pk.shape[1], pk.shape[2], pk.shape[3]
    for trial in range(6):
        b = int(rng.integers(0, q.shape[0]))
        j = int(rng.integers(0, -(-int(lens_np[b]) // bs)))
        fill = min(bs, int(lens_np[b]) - j * bs)
        blk = int(bt_np[b, j])
        flat = (((blk * hkv + int(rng.integers(0, hkv))) * bs
                 + int(rng.integers(0, fill))) * hd
                + int(rng.integers(0, hd)))
        bit = int(rng.integers(24, 31))
        into_k = bool(rng.integers(0, 2))
        pkx = flip_bit_at(pk, jnp.int32(flat), jnp.int32(bit)) if into_k \
            else pk
        pvx = pv if into_k else flip_bit_at(pv, jnp.int32(flat),
                                            jnp.int32(bit))
        rep = fn(q, pkx, pvx, kc, vc, bt, lens)
        bad = np.asarray(rep.bad_blocks)
        det = np.asarray(rep.detected)
        # the flipped block may be shared by no one else: exactly the slots
        # of requests mapping it are flagged (here tables are disjoint)
        assert bad[b, j], f"trial {trial}: flip not flagged"
        assert bad.sum() == 1, f"trial {trial}: spurious flags {bad}"
        assert det[b, 5] >= 1 and det[:, 5].sum() == det[b, 5]


@pytest.mark.quick
def test_checksum_corruption_is_also_detected(std_case):
    """Site 6 covers the checksum words themselves: a flip in the resident
    c1 plane mismatches the recomputed fold exactly like a data flip."""
    import jax.numpy as jnp
    from repro.core import checksum as cks
    from repro.core.fault import flip_bit_at

    (q, pk, pv, kc, vc, bt, lens), cfg, fn, _ = std_case
    blk = int(np.asarray(bt)[1, 0])
    hkv, cs, hd = kc.c1.shape[1], kc.c1.shape[2], kc.c1.shape[3]
    flat = ((blk * hkv + 1) * cs + 2) * hd + 3
    kc_bad = cks.Checksums(flip_bit_at(kc.c1, jnp.int32(flat),
                                       jnp.int32(26)), kc.c2)
    rep = fn(q, pk, pv, kc_bad, vc, bt, lens)
    assert np.asarray(rep.bad_blocks)[1, 0]
    assert np.asarray(rep.detected)[1, 5] >= 1


@pytest.mark.quick
def test_compute_site_seus_corrected_in_kernel(std_case):
    """High-bit SEUs at the five EFTA sites, injected through the fused
    kernel's descriptor: correct mode repairs in-kernel (output still matches
    the oracle) and reports the site."""
    import jax.numpy as jnp
    from repro.core.fault import Site

    (q, pk, pv, kc, vc, bt, lens), cfg, fn, fn_fault = std_case
    efta_out, _ = _oracles(q, pk, pv, bt, lens, cfg=cfg)
    sites = [Site.GEMM1, Site.EXP, Site.ROWMAX, Site.ROWSUM, Site.GEMM2]
    for site in sites:
        # [site, table_block, b, kv_head, group_row, col, bit, on]
        desc = jnp.asarray([int(site), 0, 1, 1, 1, 3, 27, 1], jnp.int32)
        rep = fn_fault(q, pk, pv, kc, vc, bt, lens, fault=desc)
        err = np.max(np.abs(np.asarray(rep.out) - efta_out))
        det = np.asarray(rep.detected)
        assert err < 1e-3, f"{site.name}: residual {err:.2e}"
        if site != Site.ROWMAX:   # rowmax may cancel analytically (Case 1)
            assert det[1].sum() >= 1, f"{site.name}: no detection"
        assert np.asarray(rep.bad_blocks).sum() == 0   # not a memory fault


def test_detect_mode_flags_without_correcting():
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig
    from repro.core.fault import Site
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    cfg = EFTAConfig(mode="detect", stride=8, block_kv=16)
    case = _make_case(11, B=2, mb=2, bs=16, hkv=2, grp=2, hd=16, cs=8)
    fn = jax.jit(functools.partial(efta_paged_attention_pallas, cfg=cfg,
                                   interpret=True))
    q, pk, pv, kc, vc, bt, lens = case
    desc = jnp.asarray([int(Site.GEMM2), 0, 0, 0, 0, 2, 28, 1], jnp.int32)
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens, fault=desc)
    assert np.asarray(rep.detected)[0].sum() >= 1
    # clean pool, clean run: no detections in detect mode either
    rep2 = fn(q, pk, pv, kc, vc, bt, lens)
    assert np.asarray(rep2.detected).sum() == 0


@given(st.integers(0, 10_000), st.sampled_from([8, 16]),
       st.sampled_from([(1, 1), (2, 1), (2, 2), (1, 4)]),
       st.booleans())
@settings(max_examples=6, deadline=None)
def test_parity_property_ragged_gqa_fragmented(seed, bs, heads, fragment):
    """Property sweep: random ragged lengths, permuted/fragmented tables,
    MHA/GQA/MQA ratios, two block sizes — fused == gather+EFTA == reference,
    zero detections. Loud stale rows past every valid prefix prove the
    ragged masking reads nothing it shouldn't."""
    import functools
    import jax
    from repro.core.efta import EFTAConfig
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    hkv, grp = heads
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=bs)
    case = _make_case(seed, B=2, mb=3, bs=bs, hkv=hkv, grp=grp, hd=16,
                      cs=min(8, bs), fragment=fragment, stale_scale=50.0)
    q, pk, pv, kc, vc, bt, lens = case
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens)
    efta_out, ref_out = _oracles(q, pk, pv, bt, lens, cfg=cfg)
    got = np.asarray(rep.out)
    np.testing.assert_allclose(got, efta_out, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(got, ref_out, atol=1e-4, rtol=1e-4)
    assert np.asarray(rep.detected).sum() == 0
    assert not np.asarray(rep.bad_blocks).any()


def _chunk_oracle(q, pool_k, pool_v, bt, kv_lens, q_lens, *, cfg,
                  window=None):
    """Row-by-row oracle for the multi-token chunk: chunk row c of request i
    is exactly a single-token decode at kv_len = base + c + 1 (same blocks,
    same accumulation order), so the unified kernel must reproduce the
    sequential decode bit pattern the serve engines are pinned to."""
    from repro.core.efta import efta_attention
    from repro.kernels.ops import gather_block_kv

    B, H, C, hd = q.shape
    out = np.zeros((B, H, C, hd), np.float32)
    for i in range(B):
        _, kg = gather_block_kv(pool_k[None], bt[i])
        _, vg = gather_block_kv(pool_v[None], bt[i])
        base = int(kv_lens[i]) - int(q_lens[i])
        for c in range(int(q_lens[i])):
            qi = q[i, :, c][None, :, None, :]
            o, rep = efta_attention(
                qi, kg, vg, cfg=cfg, kv_len=base + c + 1, window=window,
                causal=window is not None, q_offset=base + c)
            assert int(np.sum(np.asarray(rep.detected))) == 0
            out[i, :, c] = np.asarray(o)[0, :, 0, :]
    return out


@pytest.mark.quick
def test_chunked_q_matches_per_row_decode_oracle():
    """The unified multi-token contract at one standard shape: a C-row chunk
    per request equals C sequential single-token decodes — including rows
    whose chunk straddles a block edge — with zero detections and rows past
    q_len emitting exactly zero."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    B, mb, bs, hkv, grp, hd, cs = 3, 3, 16, 2, 2, 16, 8
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=bs)
    _, pk, pv, kc, vc, bt, lens = _make_case(
        7, B=B, mb=mb, bs=bs, hkv=hkv, grp=grp, hd=hd, cs=cs,
        stale_scale=50.0)
    rng = np.random.default_rng(2)
    C = 7                                # 7 rows over 16-blocks: straddles
    lens_np = np.asarray(lens)
    q_lens = np.minimum(C - 1, lens_np).astype(np.int32)  # also pad a row
    q = jnp.asarray(rng.standard_normal((B, hkv * grp, C, hd))
                    .astype(np.float32))
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens, jnp.asarray(q_lens))
    got = np.asarray(rep.out)
    assert got.shape == (B, hkv * grp, C, hd)
    oracle = _chunk_oracle(q, pk, pv, bt, lens_np, q_lens, cfg=cfg)
    for i in range(B):
        n = int(q_lens[i])
        np.testing.assert_allclose(got[i, :, :n], oracle[i, :, :n],
                                   atol=2e-5, rtol=2e-5)
        assert not got[i, :, n:].any()   # padding rows are exactly zero
    assert np.asarray(rep.detected).sum() == 0
    assert not np.asarray(rep.bad_blocks).any()


@given(st.integers(0, 10_000), st.sampled_from([8, 16]),
       st.sampled_from([(1, 1), (2, 2), (1, 4)]),
       st.sampled_from([3, 8, 13]))
@settings(max_examples=6, deadline=None)
def test_chunked_parity_property_matrix(seed, bs, heads, chunk):
    """Property sweep of the unified kernel: chunk widths x block sizes x
    MHA/GQA/MQA x ragged lengths x fragmented tables, chunk boundaries
    landing mid-block — chunked == sequential single-token decode, zero
    detections, loud stale rows never read."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    hkv, grp = heads
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=bs)
    _, pk, pv, kc, vc, bt, lens = _make_case(
        seed, B=2, mb=3, bs=bs, hkv=hkv, grp=grp, hd=16, cs=min(8, bs),
        stale_scale=50.0)
    rng = np.random.default_rng(seed + 1)
    lens_np = np.asarray(lens)
    q_lens = np.minimum(
        rng.integers(1, chunk + 1, size=lens_np.shape), lens_np
    ).astype(np.int32)
    q = jnp.asarray(rng.standard_normal(
        (2, hkv * grp, chunk, 16)).astype(np.float32))
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens, jnp.asarray(q_lens))
    got = np.asarray(rep.out)
    oracle = _chunk_oracle(q, pk, pv, bt, lens_np, q_lens, cfg=cfg)
    for i in range(2):
        n = int(q_lens[i])
        np.testing.assert_allclose(got[i, :, :n], oracle[i, :, :n],
                                   atol=2e-5, rtol=2e-5)
    assert np.asarray(rep.detected).sum() == 0
    assert not np.asarray(rep.bad_blocks).any()


def test_chunked_sliding_window_and_idle_rows():
    """Chunk rows apply the sliding window at their own positions (not the
    batch max), and a q_len == 0 request contributes nothing while its
    resident blocks still stream through the in-loop verify."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    cfg = EFTAConfig(mode="correct", stride=8, block_kv=16)
    _, pk, pv, kc, vc, bt, lens = _make_case(
        5, B=3, mb=3, bs=16, hkv=2, grp=2, hd=16, cs=8)
    rng = np.random.default_rng(9)
    C, win = 5, 9
    lens_np = np.asarray(lens)
    q_lens = np.minimum(C, lens_np).astype(np.int32)
    q_lens[2] = 0                        # idle slot in the mixed batch
    q = jnp.asarray(rng.standard_normal((3, 4, C, 16)).astype(np.float32))
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens, jnp.asarray(q_lens),
        window=jnp.int32(win))
    got = np.asarray(rep.out)
    oracle = _chunk_oracle(q, pk, pv, bt, lens_np, q_lens, cfg=cfg,
                           window=win)
    for i in range(2):
        n = int(q_lens[i])
        np.testing.assert_allclose(got[i, :, :n], oracle[i, :, :n],
                                   atol=2e-5, rtol=2e-5)
    assert not got[2].any()              # idle request: all-zero output
    assert np.asarray(rep.detected).sum() == 0

    # the idle request's resident corruption is still caught in-loop
    from repro.core.fault import flip_bit_at
    blk = int(np.asarray(bt)[2, 0])
    hkv_, bs_, hd_ = pk.shape[1], pk.shape[2], pk.shape[3]
    flat = ((blk * hkv_ + 0) * bs_ + 0) * hd_ + 1
    pk_bad = flip_bit_at(pk, jnp.int32(flat), jnp.int32(27))
    rep2 = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk_bad, pv, kc, vc, bt, lens, jnp.asarray(q_lens))
    assert np.asarray(rep2.bad_blocks)[2, 0]
    assert np.asarray(rep2.detected)[2, 5] >= 1


def test_chunked_compute_site_seus_corrected():
    """Compute-site SEUs injected into a chunk row (tile row = group_row *
    C + chunk_row): correct mode repairs in-kernel and reports the site,
    exactly as on the decode path."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig
    from repro.core.fault import Site
    from repro.kernels.efta_paged import efta_paged_attention_pallas

    cfg = EFTAConfig(mode="correct", stride=8, block_kv=16)
    _, pk, pv, kc, vc, bt, lens = _make_case(
        11, B=2, mb=3, bs=16, hkv=2, grp=2, hd=16, cs=8)
    rng = np.random.default_rng(4)
    C = 6
    lens_np = np.asarray(lens)
    q_lens = np.minimum(C, lens_np).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((2, 4, C, 16)).astype(np.float32))
    fn = jax.jit(lambda *a, fault: efta_paged_attention_pallas(
        *a, cfg=cfg, fault=fault, interpret=True))
    oracle = _chunk_oracle(q, pk, pv, bt, lens_np, q_lens, cfg=cfg)
    for site in (Site.GEMM1, Site.EXP, Site.ROWSUM, Site.GEMM2):
        # tile row 1*C + 2: group row 1, chunk row 2 (a valid row)
        desc = jnp.asarray([int(site), 0, 1, 1, 1 * C + 2, 3, 27, 1],
                           jnp.int32)
        rep = fn(q, pk, pv, kc, vc, bt, lens, jnp.asarray(q_lens),
                 fault=desc)
        got = np.asarray(rep.out)
        n = int(q_lens[1])
        err = np.max(np.abs(got[1, :, :n] - oracle[1, :, :n]))
        assert err < 1e-3, f"{site.name}: residual {err:.2e}"
        assert np.asarray(rep.detected)[1].sum() >= 1, site.name
        assert np.asarray(rep.bad_blocks).sum() == 0


def test_sliding_window_masks_like_the_contiguous_path():
    """Per-request window masking (traced window scalar, as the per-layer
    global/local selection passes it)."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.efta import EFTAConfig, efta_attention
    from repro.kernels.efta_paged import efta_paged_attention_pallas
    from repro.kernels.ops import gather_block_kv

    cfg = EFTAConfig(mode="correct", stride=8, block_kv=16)
    q, pk, pv, kc, vc, bt, lens = _make_case(
        5, B=2, mb=3, bs=16, hkv=2, grp=2, hd=16, cs=8)
    win = 9
    rep = jax.jit(functools.partial(
        efta_paged_attention_pallas, cfg=cfg, interpret=True))(
        q, pk, pv, kc, vc, bt, lens, window=jnp.int32(win))
    for i in range(2):
        _, kg = gather_block_kv(pk[None], bt[i])
        _, vg = gather_block_kv(pv[None], bt[i])
        o, _ = efta_attention(q[i][None, :, None, :], kg, vg, cfg=cfg,
                              kv_len=int(lens[i]), window=win,
                              causal=True, q_offset=int(lens[i]) - 1)
        np.testing.assert_allclose(np.asarray(rep.out)[i],
                                   np.asarray(o)[0, :, 0, :],
                                   atol=2e-5, rtol=2e-5)
    assert np.asarray(rep.detected).sum() == 0
