from repro.distributed.sharding import (batch_sharding, cache_shardings,
                                        dp_axes, param_shardings,
                                        spec_for_param)
from repro.distributed.collectives import compressed_psum, quantize_int8
from repro.distributed.context import DistContext, current, use_context
