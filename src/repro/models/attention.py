"""Attention block: GQA/MQA + RoPE + sliding window + cross-attention, with
the paper's EFTA as the attention implementation.

The KV cache uses slot = position % cache_len, which uniformly covers:
  * global layers  (cache_len = max_len, slot = position)
  * sliding window (cache_len = window,  ring buffer)
Keys are cached post-RoPE, so ring wraparound needs no re-rotation; masking
only needs ``kv_len`` (number of valid slots).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg, FTCfg
from repro.core.efta import EFTAConfig, FTReport
from repro.kernels.ops import attention as attention_op
from repro.models.layers import dense_init, matmul, rope


class KVCache(NamedTuple):
    k: jax.Array            # (B, Hkv, cache_len, hd)
    v: jax.Array
    pos: jax.Array          # int32 scalar: number of tokens seen so far
    # cross-attention memory (computed once at prefill; empty arrays if unused)
    ck: jax.Array
    cv: jax.Array


def efta_cfg(ft: FTCfg) -> EFTAConfig:
    return EFTAConfig(mode=ft.mode, stride=ft.stride, block_kv=ft.block_kv,
                      unified=ft.unified, shadow_rowsum=ft.shadow_rowsum,
                      shadow_rowmax=ft.shadow_rowmax, unroll=ft.scan_unroll,
                      kv_stride_override=ft.kv_stride_override,
                      out_stride_override=ft.out_stride_override)


def attn_init(key, d_model: int, a: AttnCfg, dtype, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, a.num_heads * a.head_dim, dtype),
        "wk": dense_init(ks[1], d_model, a.num_kv_heads * a.head_dim, dtype),
        "wv": dense_init(ks[2], d_model, a.num_kv_heads * a.head_dim, dtype),
        "wo": dense_init(ks[3], a.num_heads * a.head_dim, d_model, dtype),
    }
    return p


def init_cache(batch: int, a: AttnCfg, *, cache_len: int, dtype,
               cross_len: int = 0, d_model: int = 0) -> KVCache:
    shape = (batch, a.num_kv_heads, cache_len, a.head_dim)
    cshape = (batch, a.num_kv_heads, max(cross_len, 1), a.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32),
        ck=jnp.zeros(cshape, dtype), cv=jnp.zeros(cshape, dtype))


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attn_apply(
    params,
    x: jax.Array,                    # (B, S, d_model)
    *,
    acfg: AttnCfg,
    ft: FTCfg,
    window: Optional[int] = None,    # None = global (full) attention
    positions: Optional[jax.Array] = None,  # (S,) absolute positions
    cache: Optional[KVCache] = None,
    mode: str = "train",             # "train" | "prefill" | "decode"
    kv_x: Optional[jax.Array] = None,   # cross-attention memory (B, M, d)
    cross: bool = False,
    fault=None,
    mesh=None,
    interpret: bool = True,
) -> tuple[jax.Array, FTReport, Optional[KVCache]]:
    b, s, _ = x.shape
    hd, h, hkv = acfg.head_dim, acfg.num_heads, acfg.num_kv_heads
    cfg = efta_cfg(ft)
    cross = cross or (kv_x is not None)
    # Tensor-parallel attention: shard heads over 'model'. GQA groups are
    # hostile to GSPMD propagation (reshape H -> (Hkv, G) is non-divisible),
    # so under TP we materialize repeated KV heads (Megatron practice when
    # TP > kv_heads) and shard all of q/k/v on the padded head dim.
    tp = (mesh is not None and "model" in mesh.shape
          and mesh.shape["model"] > 1)

    def _tp_heads(t):
        if not tp:
            return t
        from repro.models.transformer import DP_AXES  # avoid cycle at import
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        spec = jax.sharding.PartitionSpec(dp if dp else None, "model",
                                          None, None)
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(mesh, spec))

    def _expand_kv(t):
        if not tp or t.shape[1] == h:
            return t
        g = h // t.shape[1]
        t = jnp.broadcast_to(t[:, :, None], (t.shape[0], t.shape[1], g,
                                             t.shape[2], t.shape[3]))
        return t.reshape(t.shape[0], h, t.shape[3], t.shape[4])

    def _tp_kv(t):
        # Decode: q is tiny (Sq=1) but the KV cache is huge — shard the KV
        # *head* dim over 'model' (GSPMD pads kv_heads up to the axis size)
        # instead of materializing the 7x-expanded KV. q stays replicated
        # across 'model'; the grouped einsum runs against local kv heads.
        if not tp:
            return t
        from repro.models.transformer import DP_AXES
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        spec = jax.sharding.PartitionSpec(dp if dp else None, "model",
                                          None, None)
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(mesh, spec))

    if positions is None:
        base = cache.pos if (cache is not None and mode == "decode") else 0
        positions = base + jnp.arange(s, dtype=jnp.int32)

    q = _tp_heads(_split_heads(matmul(x, params["wq"], ff_abft=ft.ff_abft),
                               h, hd))
    if cross:
        if cache is not None and mode == "decode":
            k, v = cache.ck, cache.cv
        else:
            k = _split_heads(matmul(kv_x, params["wk"], ff_abft=ft.ff_abft), hkv, hd)
            v = _split_heads(matmul(kv_x, params["wv"], ff_abft=ft.ff_abft), hkv, hd)
        if acfg.pos == "rope":
            q = rope(q.transpose(0, 2, 1, 3), positions,
                     acfg.rope_theta).transpose(0, 2, 1, 3)
        out, rep = attention_op(
            q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
            impl=ft.attn_impl, cfg=cfg, causal=False,
            sm_scale=acfg.softmax_scale, fault=fault, interpret=interpret)
        new_cache = None
        if cache is not None and mode == "prefill":
            new_cache = cache._replace(ck=k, cv=v)
        y = matmul(_merge_heads(out), params["wo"], ff_abft=ft.ff_abft)
        return y, rep, new_cache

    k = _split_heads(matmul(x, params["wk"], ff_abft=ft.ff_abft), hkv, hd)
    v = _split_heads(matmul(x, params["wv"], ff_abft=ft.ff_abft), hkv, hd)
    if acfg.pos == "rope":
        q = rope(q.transpose(0, 2, 1, 3), positions,
                 acfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k.transpose(0, 2, 1, 3), positions,
                 acfg.rope_theta).transpose(0, 2, 1, 3)

    new_cache = None
    if cache is None:
        # Training / encoding: self-attention over the full sequence.
        out, rep = attention_op(
            q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
            impl=ft.attn_impl, cfg=cfg, causal=acfg.causal,
            window=window, sm_scale=acfg.softmax_scale, fault=fault,
            interpret=interpret)
    else:
        cache_len = cache.k.shape[2]
        slots = positions % cache_len
        ck = cache.k.at[:, :, slots, :].set(k.astype(cache.k.dtype))
        cv = cache.v.at[:, :, slots, :].set(v.astype(cache.v.dtype))
        new_pos = positions[-1] + 1
        new_cache = cache._replace(k=ck, v=cv, pos=new_pos)
        if mode == "prefill":
            # Attend within the prompt itself (fresh cache).
            out, rep = attention_op(
                q, _tp_heads(_expand_kv(k)), _tp_heads(_expand_kv(v)),
                impl=ft.attn_impl, cfg=cfg, causal=acfg.causal,
                window=window, sm_scale=acfg.softmax_scale, fault=fault,
                interpret=interpret)
        else:
            # Decode: attend over the valid region of the (ring) cache.
            # Each slot's absolute position is reconstructed so causal and
            # sliding-window masks apply exactly even after wraparound.
            slot_idx = jnp.arange(cache_len, dtype=jnp.int32)
            last_written = new_pos - 1 - ((new_pos - 1 - slot_idx) % cache_len)
            kv_positions = jnp.where(last_written >= 0, last_written, -1)
            out, rep = attention_op(
                q, _tp_kv(ck), _tp_kv(cv),
                impl="efta" if ft.attn_impl == "efta_pallas"
                else ft.attn_impl,
                cfg=cfg, causal=True, window=window,
                q_offset=positions[0], kv_positions=kv_positions,
                sm_scale=acfg.softmax_scale, fault=fault, interpret=interpret)
    y = matmul(_merge_heads(out), params["wo"], ff_abft=ft.ff_abft)
    return y, rep, new_cache
