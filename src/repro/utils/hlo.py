"""HLO text analysis: collective-byte accounting for the roofline.

``cost_analysis`` gives FLOPs and HBM bytes but not collective traffic, so we
parse the (post-SPMD-partitioning) HLO and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind over the whole module.

    Post-optimization HLO prints operands untyped (%name only), so we take
    the result shape(s) printed between ``=`` and the op name. For all-reduce
    result==operand; for all-gather the result is the wire-received volume;
    for reduce-scatter this undercounts by the group factor (noted).

    NOTE: ops inside ``while`` bodies are counted once; callers that need
    per-iteration accounting extrapolate via layer probes (launch/dryrun.py).
    """
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:  # async pairs: count starts
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        eq = line.find("=")
        if eq < 0 or eq > m.start():
            continue
        kind = m.group(1)
        total = 0
        for sm in _SHAPE_RE.finditer(line[eq:m.start()]):
            total += _shape_bytes(sm.group(1), sm.group(2))
        out[kind] += total
    return dict(out)


def count_ops(hlo_text: str, names=("fusion", "custom-call", "while",
                                    "dot", "convolution")) -> dict[str, int]:
    counts = {}
    for n in names:
        counts[n] = len(re.findall(rf"\b{n}\(", hlo_text))
    return counts
