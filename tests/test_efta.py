"""EFTA core: equivalence with naive attention + fault injection coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EFTAConfig, FaultSpec, Site, efta_attention,
                        reference_attention)

pytestmark = pytest.mark.quick

CFG = EFTAConfig(mode="correct", stride=8, block_kv=16)


def qkv(b=2, h=4, hkv=2, s=64, d=32, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, s, d), dtype),
            jax.random.normal(ks[1], (b, hkv, s, d), dtype),
            jax.random.normal(ks[2], (b, hkv, s, d), dtype))


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_reference(causal, dtype):
    q, k, v = qkv(dtype=dtype)
    out, rep = efta_attention(q, k, v, cfg=CFG, causal=causal)
    ref = reference_attention(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
    assert int(rep.detected.sum()) == 0  # no false positives


@pytest.mark.parametrize("s,d,block", [(32, 16, 8), (64, 32, 32), (96, 64, 32),
                                       (128, 16, 128)])
def test_shape_sweep(s, d, block):
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=block)
    q, k, v = qkv(s=s, d=d)
    out, _ = efta_attention(q, k, v, cfg=cfg)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=3e-6)


def test_window_and_ragged():
    q, k, v = qkv()
    out, _ = efta_attention(q, k, v, cfg=CFG, causal=True, window=24)
    ref = reference_attention(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(out, ref, atol=2e-6)
    out2, _ = efta_attention(q, k, v, cfg=CFG, kv_len=jnp.int32(37))
    ref2 = reference_attention(q, k, v, kv_len=37)
    np.testing.assert_allclose(out2, ref2, atol=2e-6)


def test_kv_positions_ring_cache():
    """kv_positions reconstructs masks for wrapped ring caches."""
    q, k, v = qkv(s=32)
    q1 = q[:, :, -1:, :]
    # pretend k/v slots hold positions [32..63] shuffled by ring wrap
    perm = (jnp.arange(32) + 11) % 32
    kv_pos = 32 + jnp.argsort(perm)  # position stored in each slot
    k_r = k[:, :, perm, :]
    v_r = v[:, :, perm, :]
    # equivalent unwrapped computation
    ref = reference_attention(q1, k, v, causal=True, q_offset=63,
                              kv_positions=jnp.arange(32) + 32)
    out, _ = efta_attention(q1, k_r, v_r, cfg=CFG, causal=True, q_offset=63,
                            kv_positions=kv_pos)
    np.testing.assert_allclose(out, ref, atol=2e-6)


@pytest.mark.parametrize("site", [Site.GEMM1, Site.EXP, Site.ROWMAX,
                                  Site.ROWSUM, Site.GEMM2])
def test_fault_corrected(site):
    q, k, v = qkv()
    ref = reference_attention(q, k, v)
    f = FaultSpec.single(site, block=1, batch=0, head=1, row=5, col=3, bit=26)
    out, rep = efta_attention(q, k, v, cfg=CFG, fault=f)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, f"{site.name}: corrected err {err}"
    if site != Site.ROWMAX:
        assert int(rep.detected.sum()) >= 1 or site == Site.ROWMAX


def test_fault_uncorrected_does_damage():
    """Sanity: without FT the same fault visibly corrupts the output."""
    q, k, v = qkv()
    ref = reference_attention(q, k, v)
    f = FaultSpec.single(Site.GEMM2, block=1, batch=0, head=1, row=5,
                         col=3, bit=28)
    off = EFTAConfig(mode="off", stride=8, block_kv=16)
    out, _ = efta_attention(q, k, v, cfg=off, fault=f)
    assert float(jnp.max(jnp.abs(out - ref))) > 1e-2


def test_detect_mode_counts_but_does_not_fix():
    q, k, v = qkv()
    ref = reference_attention(q, k, v)
    f = FaultSpec.single(Site.GEMM1, block=0, batch=0, head=0, row=1,
                         col=2, bit=27)
    det = EFTAConfig(mode="detect", stride=8, block_kv=16)
    out, rep = efta_attention(q, k, v, cfg=det, fault=f)
    assert int(rep.detected.sum()) >= 1
    assert int(rep.corrected.sum()) == 0
    assert float(jnp.max(jnp.abs(out - ref))) > 1e-4


def test_paper_mode_rowsum_approximation():
    """shadow_rowsum=False reproduces the paper's analytic fallback: detected
    and bounded, but only approximately corrected."""
    q, k, v = qkv()
    ref = reference_attention(q, k, v)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=16,
                     shadow_rowsum=False)
    f = FaultSpec.single(Site.ROWSUM, block=1, batch=0, head=1, row=5,
                         col=0, bit=26)
    out, rep = efta_attention(q, k, v, cfg=cfg, fault=f)
    assert int(rep.detected[3]) >= 1
    assert bool(jnp.all(jnp.isfinite(out)))


def test_gqa_grouping():
    q, k, v = qkv(h=8, hkv=2)
    out, _ = efta_attention(q, k, v, cfg=CFG)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-6)


def test_differentiable():
    q, k, v = qkv()
    g = jax.grad(lambda q: efta_attention(q, k, v, cfg=CFG)[0].sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_decode_offset():
    q, k, v = qkv()
    q1 = q[:, :, -1:, :]
    out, _ = efta_attention(q1, k, v, cfg=CFG, causal=True, q_offset=63)
    ref = reference_attention(q1, k, v, causal=True, q_offset=63)
    np.testing.assert_allclose(out, ref, atol=2e-6)
