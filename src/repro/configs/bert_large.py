"""bert-large (paper Table 3): 24L 16H head_dim=64 encoder-only."""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="bert-large", family="encoder",
    num_layers=24, d_model=1024, d_ff=4096, vocab_size=30522,
    attn=AttnCfg(num_heads=16, num_kv_heads=16, head_dim=64, pos="learned",
                 causal=False),
    norm="layernorm", glu=False, act="gelu", max_seq=512,
    source="paper Table 3",
)
