"""Per-request token sampling for the serve engine.

Everything here is jit-friendly at fixed batch shape: per-request sampling
parameters ride along as arrays (temperature, top-k, PRNG key per row), so one
compiled ``sample_tokens`` serves an arbitrary mix of greedy and stochastic
requests in the same batch. ``temperature == 0`` rows take the exact
``argmax`` path (bit-identical to the sequential greedy decoder).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (host-side)."""

    temperature: float = 0.0   # 0 => greedy (exact argmax)
    top_k: int = 0             # 0 => no truncation
    seed: int = 0              # per-request PRNG stream

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")


def request_key(params: SamplingParams, rid: int) -> jax.Array:
    """Stable per-request PRNG key: independent streams even when two
    requests share a seed."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), rid)


def _top_k_mask(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Mask logits below each row's k-th largest value. ``top_k`` (B,) int32;
    0 disables truncation for that row (k clamps to the full vocab)."""
    vocab = logits.shape[-1]
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, vocab), vocab)
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    return jnp.where(logits >= kth, logits, -jnp.inf)


def sample_tokens(logits: jax.Array, *, temperature: jax.Array,
                  top_k: jax.Array, keys: jax.Array) -> jax.Array:
    """Sample one token per row. logits (B, V) f32; temperature (B,) f32;
    top_k (B,) int32; keys (B,) PRNG keys. Returns (B,) int32.

    Stochastic rows use the Gumbel-max trick (exactly equivalent to
    categorical sampling over the top-k-truncated, temperature-scaled
    distribution); greedy rows bypass noise entirely.
    """
    greedy = temperature <= 0.0
    masked = _top_k_mask(logits, top_k)
    t_safe = jnp.where(greedy, 1.0, jnp.maximum(temperature, 1e-6))
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, logits.shape[-1:],
                                                  jnp.float32))(keys)
    stochastic = jnp.argmax(masked / t_safe[:, None] + gumbel, axis=-1)
    return jnp.where(greedy, jnp.argmax(logits, axis=-1),
                     stochastic).astype(jnp.int32)
