"""AdamW vs a numpy reference; schedules; low-precision state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, warmup_cosine
import pytest

pytestmark = pytest.mark.quick


def test_adamw_matches_reference():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = opt.init(p)
    new_p, st = opt.update(g, st, p)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    step = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    np.testing.assert_allclose(new_p["w"], np.asarray(p["w"]) - 0.1 * step,
                               rtol=1e-5)


def test_weight_decay_and_clip():
    opt = AdamW(lr=0.1, weight_decay=0.1, clip_norm=1e-6)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.ones((3,)) * 100}
    st = opt.init(p)
    new_p, _ = opt.update(g, st, p)  # gradient clipped to ~0 -> wd dominates
    assert float(new_p["w"][0]) < 1.0


def test_bf16_state():
    opt = AdamW(lr=0.1, state_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt.init(p)
    assert st.m["w"].dtype == jnp.bfloat16
    new_p, st2 = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, st, p)
    assert new_p["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup=10, total=100, floor=0.1)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) <= 0.1 + 1e-6
