from repro.serve.step import greedy_generate, make_decode_step, make_prefill_step
