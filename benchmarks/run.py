"""Benchmark entrypoint: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run           # all
  PYTHONPATH=src python -m benchmarks.run fig09 ... # subset
"""
import sys
import traceback

from benchmarks import (bench_fig09_decoupled_vs_efta,
                        bench_fig10_overhead_breakdown,
                        bench_fig11_abft_variants,
                        bench_fig12_error_coverage,
                        bench_fig13_snvr_vs_dmr,
                        bench_fig14_snvr_distribution,
                        bench_tab12_unified_verification,
                        bench_fig15_model_overhead,
                        bench_paged_attention,
                        bench_paged_cache,
                        bench_serve_throughput,
                        roofline)

ALL = {
    "fig09": bench_fig09_decoupled_vs_efta.run,
    "fig10": bench_fig10_overhead_breakdown.run,
    "fig11": bench_fig11_abft_variants.run,
    "fig12": bench_fig12_error_coverage.run,
    "fig13": bench_fig13_snvr_vs_dmr.run,
    "fig14": bench_fig14_snvr_distribution.run,
    "tab12": bench_tab12_unified_verification.run,
    "fig15": bench_fig15_model_overhead.run,
    "serve": bench_serve_throughput.run,
    "paged": bench_paged_cache.run,
    "paged_attn": bench_paged_attention.run,
    "roofline": roofline.run,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    failed = []
    for n in names:
        try:
            ALL[n]()
        except Exception:
            failed.append(n)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
