"""Roofline reporter: renders experiments/dryrun/*.json into the
EXPERIMENTS.md tables (per arch x shape x mesh: three terms, dominant
bottleneck, MODEL_FLOPS ratio, one-line lever)."""
import json
from pathlib import Path

LEVERS = {
    "compute_s": "cut HLO FLOPs: causal block skipping, drop remat recompute, narrower checksums",
    "memory_s": "cut HBM traffic: Pallas-fused attention (S/P stay in VMEM), bf16 intermediates, seq-parallel residuals",
    "collective_s": "cut bytes on ICI: int8 gradient sync, fewer all-gathers via better layouts, overlap with compute",
}


def load(out_dir="experiments/dryrun"):
    rows = []
    for p in sorted(Path(out_dir).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def render(rows, *, mesh="16x16", tagged=None):
    print(f"| arch | shape | compute_s | memory_s | collective_s | dominant "
          f"| peak GB | fits16GB | MODEL_FLOPS/HLO | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh or r.get("tag", "") != (tagged or ""):
            continue
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} "
              f"| {t['memory_s']:.2e} | {t['collective_s']:.2e} "
              f"| {r['dominant'][:-2]} | {r['memory']['peak_bytes']/1e9:.1f} "
              f"| {r['memory']['fits_16gb']} "
              f"| {r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)} "
              f"| {r['roofline_fraction'] and round(r['roofline_fraction'],4)} |")


def run():
    rows = load()
    if not rows:
        print("# roofline: no dryrun artifacts yet (run repro.launch.dryrun)")
        return []
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## mesh {mesh}")
        render(rows, mesh=mesh)
    return rows


if __name__ == "__main__":
    run()
