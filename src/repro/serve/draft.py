"""Draft-token proposers: the *propose* stage of propose→score→accept.

The unified serve step (``repro.serve.paged``) generalized "prefill a chunk
OR decode one token" into one contract: each slot proposes K candidate
tokens (K = 0 degenerates to plain decode), the unified chunked program
scores them in one EFTA-protected launch, and the acceptance stage
(``repro.serve.sampling.speculative_accept``) commits the longest valid
prefix. This module supplies the proposers:

  * :class:`NGramProposer` — self-drafting prompt-lookup: match the tail
    n-gram of the request's committed tokens against an earlier occurrence
    in its own context and propose the continuation that followed it. Zero
    model cost, deterministic, and strongest exactly where speculation pays
    (repetitive suffixes: code, templated text, self-consistency replays).
  * :class:`DraftModelProposer` — a small draft model decoded greedily
    through the SAME EFTA-protected path as the target (``Model.extend`` /
    the pure-JAX EFTA attention): a compute SEU striking the draft forward
    is detected by the draft model's own EFTA scheme and the proposal
    attempt retries clean. Even an *undetected* draft corruption can only
    mis-propose — the target's scoring pass validates every committed
    token, so a flipped bit in either pass costs a rejected draft, never a
    silently wrong accepted token (the paper's end-to-end thesis applied to
    speculation).

Proposers are host-driven between jitted steps and per-slot stateful. The
draft model keeps one batch-1 ring KV cache per slot and *rolls back* to
the committed context by position rewind: the longest-common-prefix rule in
:meth:`DraftModelProposer.propose` rewinds the cache position to the last
token both the cache and the new committed context agree on, so target-side
rejections never desynchronize the draft cache (stale ring entries past the
rewound position are masked by the ``kv_positions`` reconstruction and
overwritten on the next feed).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.fault import FaultSpec


@dataclasses.dataclass
class DraftStats:
    """Host-side proposer telemetry (the engine folds the per-proposal
    detect/correct vectors into the per-request draft-pass counters)."""

    proposals: int = 0          # propose() calls that returned >= 1 token
    proposed_tokens: int = 0
    detected: int = 0           # draft-pass EFTA detections (all sites)
    retries: int = 0            # draft forward attempts retried on detect


class NGramProposer:
    """Self-drafting prompt-lookup proposer (no draft model).

    Finds the most recent earlier occurrence of the context's tail n-gram
    (longest n first) and proposes the tokens that followed it. Returns an
    empty proposal when nothing matches — the slot then runs the K = 0
    degenerate path, i.e. plain decode.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.stats = DraftStats()

    def propose(self, slot: int, tokens: np.ndarray, k: int) -> np.ndarray:
        """Up to ``k`` draft tokens continuing ``tokens`` (the request's
        prompt + committed generation, pending token included)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        t = tokens.size
        if k <= 0 or t < self.min_ngram + 1:
            return np.zeros((0,), np.int32)
        for n in range(min(self.max_ngram, t - 1), self.min_ngram - 1, -1):
            pat = tokens[t - n:]
            # rightmost earlier occurrence: windows [i, i+n) for i < t - n
            wins = np.lib.stride_tricks.sliding_window_view(tokens[:-1], n)
            hits = np.flatnonzero((wins[:t - n] == pat).all(axis=1))
            if hits.size == 0:
                continue
            i = int(hits[-1])
            cont = tokens[i + n:i + n + k]
            if cont.size:
                self.stats.proposals += 1
                self.stats.proposed_tokens += int(cont.size)
                return cont.astype(np.int32)
        return np.zeros((0,), np.int32)

    def release(self, slot: int) -> None:
        pass

    def drain_report(self):
        """No model forward — nothing to report. Matches the
        :class:`DraftModelProposer` interface."""
        return None


class DraftModelProposer:
    """Greedy small-draft-model proposer over per-slot ring KV caches.

    The draft forward runs through the exact EFTA path the target uses
    (``Model.extend``): per-attempt ``FTReport``s are accumulated, and an
    attempt whose detections could not be exactly corrected is retried
    clean (SEUs are transient), mirroring the serve engine's
    retry-on-detect. ``fault_next`` lets fault campaigns strike the *draft*
    pass: the spec is consumed by the first attempt of the next draft
    forward.

    Chunk feeds are fixed-width (``chunk_size``) so the proposer compiles
    exactly two programs (feed width + decode width 1) regardless of how
    contexts grow or rewind.
    """

    def __init__(self, model, params, *, n_slots: int, cache_len: int,
                 chunk_size: int = 16, max_retries: int = 2):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.chunk_size = min(chunk_size, cache_len)
        self.max_retries = max_retries
        self._exact_rowsum = model.cfg.ft.shadow_rowsum
        self._fed: List[List[int]] = [[] for _ in range(n_slots)]
        self._cache: List[Optional[object]] = [None] * n_slots
        self.stats = DraftStats()
        self.fault_next: Optional[FaultSpec] = None
        # pending (det[5], cor[5], retries) for the engine's draft telemetry
        self._report = None
        self._extend = jax.jit(
            lambda p, t, c, l, f: model.extend(p, t, c, lengths=l, fault=f))

    # -- EFTA plumbing ------------------------------------------------------

    def _needs_retry(self, rep) -> bool:
        det = np.asarray(rep.detected).reshape(-1)[:5]
        cor = np.asarray(rep.corrected).reshape(-1)[:5]
        uncorrected = det.sum() - cor.sum()
        approx = 0 if self._exact_rowsum else cor[3]
        return bool(uncorrected > 0 or approx > 0)

    def _guarded_extend(self, tokens: np.ndarray, cache, length: int,
                        det_acc, cor_acc):
        """One EFTA-protected draft forward with retry-on-detect. The first
        attempt consumes ``fault_next`` (campaign injection); retries run
        clean."""
        fault = self.fault_next if self.fault_next is not None \
            else FaultSpec.none(1)
        self.fault_next = None
        toks = jnp.asarray(tokens)
        length = jnp.asarray([length], jnp.int32)
        logits, rep, new_cache = self._extend(
            self.params, toks, cache, length, fault)
        det_acc += np.asarray(rep.detected, np.int64).reshape(-1)[:5]
        cor_acc += np.asarray(rep.corrected, np.int64).reshape(-1)[:5]
        retries = 0
        while self._needs_retry(rep) and retries < self.max_retries:
            retries += 1
            logits, rep, new_cache = self._extend(
                self.params, toks, cache, length, FaultSpec.none(1))
            det_acc += np.asarray(rep.detected, np.int64).reshape(-1)[:5]
            cor_acc += np.asarray(rep.corrected, np.int64).reshape(-1)[:5]
        return logits, new_cache, retries

    # -- cache lifecycle ----------------------------------------------------

    def _rewind(self, slot: int, n: int) -> None:
        """Roll the slot's draft cache back to its first ``n`` fed tokens
        (position rewind; stale ring entries are masked + overwritten)."""
        self._fed[slot] = self._fed[slot][:n]
        cache = self._cache[slot]
        if cache is None:
            return
        from repro.serve.cache import map_kv_nodes
        self._cache[slot] = map_kv_nodes(
            cache, lambda c: c._replace(
                pos=jnp.full_like(c.pos, jnp.int32(n))))

    def release(self, slot: int) -> None:
        self._fed[slot] = []
        self._cache[slot] = None

    def drain_report(self):
        """Hand the engine the (det[5], cor[5], retries) accumulated by the
        last :meth:`propose` call (draft-pass telemetry), then clear it."""
        r, self._report = self._report, None
        return r

    # -- proposing ----------------------------------------------------------

    def propose(self, slot: int, tokens: np.ndarray, k: int) -> np.ndarray:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        k = min(int(k), self.cache_len - int(tokens.size))
        if k <= 0:
            return np.zeros((0,), np.int32)
        if tokens.size > self.cache_len - self.chunk_size:
            # near draft-cache capacity a feed chunk would have to narrow
            # (a ring wrap would clobber context) and compile a third
            # program — fall back to K = 0 instead, mirroring the serve
            # engine's near-boundary behavior
            return np.zeros((0,), np.int32)
        det_acc = np.zeros((5,), np.int64)
        cor_acc = np.zeros((5,), np.int64)
        retries = 0

        fed = self._fed[slot]
        common = 0
        limit = min(len(fed), tokens.size)
        while common < limit and fed[common] == int(tokens[common]):
            common += 1
        if self._cache[slot] is None:
            self._cache[slot] = self.model.init_cache(
                1, cache_len=self.cache_len)
            common = 0
            self._fed[slot] = []
        if common < len(fed):
            self._rewind(slot, common)      # target rejected a draft suffix
        fed = self._fed[slot]

        # feed the committed tokens the draft cache has not seen, in fixed-
        # width chunks; the final chunk's logits seed the greedy draft loop.
        # A padded chunk advances the ring position by its full width and
        # writes junk rows past the fill — rewind to the true fed length so
        # the padding is masked out of every subsequent attention.
        delta = tokens[len(fed):]
        logits = None
        i = 0
        while i < delta.size:
            w = self.chunk_size          # fixed width: exactly two programs
            fill = min(w, delta.size - i)
            buf = np.zeros((1, w), np.int32)
            buf[0, :fill] = delta[i:i + fill]
            logits, self._cache[slot], r = self._guarded_extend(
                buf, self._cache[slot], fill, det_acc, cor_acc)
            retries += r
            fed_now = self._fed[slot] + [int(x) for x in delta[i:i + fill]]
            self._fed[slot] = fed_now
            if fill < w:
                self._rewind(slot, len(fed_now))
            i += fill
        if logits is None:
            # cache already holds the full context (pure rewind): re-score
            # the last committed token to recover its next-token logits
            self._rewind(slot, tokens.size - 1)
            buf = np.asarray(tokens[-1:][None], np.int32)
            logits, self._cache[slot], r = self._guarded_extend(
                buf, self._cache[slot], 1, det_acc, cor_acc)
            retries += r
            self._fed[slot].append(int(tokens[-1]))

        # greedy autoregressive drafting (one-hot q): k tokens, k-1 feeds
        drafts: List[int] = []
        for j in range(k):
            d = int(np.argmax(np.asarray(logits, np.float32).reshape(-1)))
            drafts.append(d)
            if j == k - 1:
                break
            buf = np.asarray([[d]], np.int32)
            logits, self._cache[slot], r = self._guarded_extend(
                buf, self._cache[slot], 1, det_acc, cor_acc)
            retries += r
            self._fed[slot].append(d)

        self.stats.proposals += 1
        self.stats.proposed_tokens += len(drafts)
        self.stats.detected += int(det_acc.sum())
        self.stats.retries += retries
        self._report = (det_acc, cor_acc, retries)
        return np.asarray(drafts, np.int32)


def build_proposer(kind: str, *, n_slots: int, cache_len: int,
                   chunk_size: int, draft_model=None, draft_params=None,
                   max_ngram: int = 3):
    """Proposer factory for ``PagedServeEngine(speculate=...)``."""
    if kind == "ngram":
        return NGramProposer(max_ngram=max_ngram)
    if kind == "draft":
        if draft_model is None or draft_params is None:
            raise ValueError(
                "speculate='draft' needs draft_model and draft_params")
        return DraftModelProposer(draft_model, draft_params, n_slots=n_slots,
                                  cache_len=cache_len, chunk_size=chunk_size)
    raise ValueError(f"unknown proposer kind {kind!r} "
                     "(expected 'ngram' or 'draft')")
