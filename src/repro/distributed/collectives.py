"""Distributed-optimization collectives.

``compressed_psum``: int8-quantized all-reduce with error feedback — cuts
cross-pod gradient bytes 4x (bf16) / 8x (f32). Used by the train step's
``pod_sync="int8_ef"`` mode: the slow cross-pod links carry int8 payloads
while the in-pod reduction stays full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str, err: jax.Array | None = None):
    """int8 psum over ``axis`` with error feedback.

    Must run inside shard_map with ``axis`` manual. A *global* scale is
    agreed first (one scalar max-reduce — negligible vs the payload) so the
    int8 sums commute exactly with dequantization. Returns (mean, new_err):
    the local quantization residual is carried to the next step (error
    feedback keeps compressed SGD unbiased over time).
    """
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis)   # scalar on the wire
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = (xf - q.astype(jnp.float32) * scale).astype(
        err.dtype if err is not None else jnp.float32)
    # int8 payloads cross the link; accumulate in i32 to avoid overflow.
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = total.astype(jnp.float32) * scale / n
    return mean.astype(x.dtype), new_err
