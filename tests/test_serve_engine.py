"""Continuous-batching serve engine: batched output must be token-identical
to per-request sequential decoding, slots must be reused safely, and injected
decode-step faults must trigger retry without changing final tokens."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import FaultSpec, Site
from repro.models import build_model
from repro.serve import SamplingParams, ServeEngine, batch_faults, greedy_generate

LENGTHS = [5, 9, 16, 3, 12, 7]
STEPS = [6, 4, 8, 5, 3, 7]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in LENGTHS]
    return cfg, model, params, prompts


@pytest.fixture(scope="module")
def sequential_refs(setup):
    """Per-request batch-1 greedy decoding — the exactness oracle."""
    _, model, params, prompts = setup
    refs = []
    for p, s in zip(prompts, STEPS):
        out, _ = greedy_generate(model, params, jnp.asarray(p[None]), steps=s)
        refs.append(np.asarray(out)[0])
    return refs


def test_continuous_batching_matches_sequential(setup, sequential_refs):
    """Mixed-length prompts, more requests than slots (staggered admission +
    slot reuse after eviction): every request's tokens must equal its
    sequential batch-1 decode exactly."""
    _, model, params, prompts = setup
    eng = ServeEngine(model, params, n_slots=3, cache_len=48)
    for p, s in zip(prompts, STEPS):
        eng.submit(p, max_new_tokens=s)
    outs = eng.run()
    assert set(outs) == set(range(len(prompts)))
    for rid, ref in enumerate(sequential_refs):
        np.testing.assert_array_equal(outs[rid], ref, err_msg=f"rid={rid}")
    # continuous batching actually batched: fewer engine steps than the sum
    # of sequential decode steps
    assert eng.stats.steps < sum(STEPS)
    # all three slots served more than one request (reuse after eviction)
    assert eng.stats.prefills == len(prompts)


def test_single_slot_degenerates_to_sequential(setup, sequential_refs):
    _, model, params, prompts = setup
    eng = ServeEngine(model, params, n_slots=1, cache_len=48)
    for p, s in zip(prompts[:3], STEPS[:3]):
        eng.submit(p, max_new_tokens=s)
    outs = eng.run()
    for rid in range(3):
        np.testing.assert_array_equal(outs[rid], sequential_refs[rid])


def test_late_submission_joins_running_batch(setup, sequential_refs):
    """Requests submitted while the engine is mid-flight are admitted into
    free slots and still decode exactly."""
    _, model, params, prompts = setup
    eng = ServeEngine(model, params, n_slots=4, cache_len=48)
    eng.submit(prompts[0], max_new_tokens=STEPS[0])
    eng.submit(prompts[1], max_new_tokens=STEPS[1])
    eng.step()
    eng.step()
    eng.submit(prompts[2], max_new_tokens=STEPS[2])
    outs = eng.run()
    for rid in range(3):
        np.testing.assert_array_equal(outs[rid], sequential_refs[rid])


def test_eos_stops_generation(setup):
    _, model, params, prompts = setup
    eng = ServeEngine(model, params, n_slots=2, cache_len=48)
    # run one request greedily, find a token it actually emits, then use it
    # as the EOS id for a fresh run
    rid = eng.submit(prompts[0], max_new_tokens=6)
    probe = eng.run()[rid]
    eos = int(probe[2])
    eng2 = ServeEngine(model, params, n_slots=2, cache_len=48)
    rid2 = eng2.submit(prompts[0], max_new_tokens=6, eos_id=eos)
    out = eng2.run()[rid2]
    stop = int(np.argmax(out == eos)) if (out == eos).any() else len(out) - 1
    assert len(out) == stop + 1  # nothing generated past EOS


def test_stochastic_sampling_reproducible_and_per_request(setup):
    """Same seed => identical tokens across engine runs; different seeds
    diverge (per-request PRNG streams, not a shared one)."""
    _, model, params, prompts = setup

    def run(seed):
        eng = ServeEngine(model, params, n_slots=2, cache_len=48)
        sp = SamplingParams(temperature=1.5, top_k=20, seed=seed)
        r = eng.submit(prompts[0], max_new_tokens=8, sampling=sp)
        return eng.run()[r]

    a, b, c = run(7), run(7), run(8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_decode_fault_triggers_retry_with_unchanged_tokens(setup):
    """A detect-mode model cannot correct in-kernel; the engine must catch
    the per-slot FTReport, retry the step clean, and commit tokens identical
    to a fault-free run."""
    cfg, _, _, prompts = setup
    det_cfg = dataclasses.replace(
        cfg, ft=dataclasses.replace(cfg.ft, mode="detect"))
    model = build_model(det_cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(faults_by_step):
        eng = ServeEngine(model, params, n_slots=2, cache_len=48)
        for p in prompts[:2]:
            eng.submit(p, max_new_tokens=6)
        return eng, eng.run(faults_by_step)

    _, clean = run(None)
    f = FaultSpec.single(Site.GEMM2, block=0, batch=0, head=1, row=0,
                         col=3, bit=28)
    eng, faulty = run({1: batch_faults(2, {0: f, 1: f}),
                       3: batch_faults(2, {1: f})})
    for rid in clean:
        np.testing.assert_array_equal(clean[rid], faulty[rid])
    assert eng.stats.retries >= 2
    summ = eng.telemetry.summary()
    assert summ["detected"] > 0 and summ["retries"] >= 2
    # per-request aggregation: both requests saw detections on step 1
    for rid in (0, 1):
        st = eng.telemetry.requests[rid]
        assert st.total_detected > 0
        assert st.retries > 0
        assert 0.0 < st.detection_rate <= 1.0


def test_correct_mode_fault_corrected_in_kernel_no_retry(setup):
    """In correct mode EFTA repairs the SEU inside the kernel: tokens match
    the clean run with zero engine-level retries."""
    cfg, model, params, prompts = setup

    def run(faults_by_step):
        eng = ServeEngine(model, params, n_slots=2, cache_len=48)
        for p in prompts[:2]:
            eng.submit(p, max_new_tokens=5)
        return eng, eng.run(faults_by_step)

    _, clean = run(None)
    f = FaultSpec.single(Site.GEMM1, block=0, batch=0, head=0, row=0,
                         col=2, bit=27)
    eng, faulty = run({1: batch_faults(2, {0: f})})
    for rid in clean:
        np.testing.assert_array_equal(clean[rid], faulty[rid])
    assert eng.stats.retries == 0
    assert eng.telemetry.requests[0].total_corrected > 0


def test_failed_admission_keeps_fcfs_queue_position():
    """Regression (scheduler fairness): a request that repeatedly fails
    resource allocation — e.g. the paged engine cannot assemble its KV
    blocks yet — must keep its FCFS queue position. A smaller request
    behind it must never jump the queue."""
    from repro.serve import ContinuousBatchingScheduler, Request

    sched = ContinuousBatchingScheduler(2)
    big = Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                  max_new_tokens=1)
    small = Request(rid=1, prompt=np.arange(2, dtype=np.int32),
                    max_new_tokens=1)
    sched.add(big)
    sched.add(small)
    admitted = []
    denies = {"left": 3}

    def try_admit(req):
        # resources exist for the small request throughout, but the head of
        # the queue (big) is denied three times — FCFS requires head-of-line
        # blocking, not queue-jumping
        if req.rid == 0 and denies["left"]:
            denies["left"] -= 1
            return None
        return len(admitted)

    for _ in range(6):
        admitted.extend(
            r.rid for r in sched.step(try_admit, lambda r: None).admitted)
    assert admitted == [0, 1]
    assert denies["left"] == 0   # the denial path was actually exercised


def test_per_request_telemetry_isolates_faulty_slot(setup):
    """A fault aimed at one slot must not pollute the other request's
    fault accounting."""
    cfg, _, _, prompts = setup
    det_cfg = dataclasses.replace(
        cfg, ft=dataclasses.replace(cfg.ft, mode="detect"))
    model = build_model(det_cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=2, cache_len=48,
                      retry_on_detect=False)
    r0 = eng.submit(prompts[0], max_new_tokens=4)
    r1 = eng.submit(prompts[1], max_new_tokens=4)
    f = FaultSpec.single(Site.GEMM2, block=0, batch=0, head=1, row=0,
                         col=3, bit=28)
    eng.run({1: batch_faults(2, {0: f})})
    assert eng.telemetry.requests[r0].total_detected > 0
    assert eng.telemetry.requests[r1].total_detected == 0
