"""End-to-end system test: train -> fault-tolerant checkpoint -> crash ->
resume -> serve, with EFTA protecting attention throughout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FaultSpec, Site
from repro.data import make_pipeline
from repro.ft_runtime import latest_step, restore, save
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import greedy_generate
from repro.train import init_state, make_train_step
import pytest

pytestmark = pytest.mark.quick


def test_train_checkpoint_crash_resume_serve(tmp_path):
    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    data = make_pipeline(cfg, global_batch=4, seq_len=32, seed=1)
    step_fn = jax.jit(make_train_step(model, opt))

    # --- run A: train 6 steps, checkpoint at 4, "crash" -------------------
    state = init_state(model, opt, jax.random.PRNGKey(0))
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, _ = step_fn(state, batch)
        if i == 3:
            save(tmp_path / "step_4", state, step=4)
    batch6 = {k: jnp.asarray(v) for k, v in data.batch(6).items()}
    _, m_a = step_fn(state, batch6)
    loss_a = float(m_a["loss"])

    # --- run B: restore at 4, replay steps 4,5 (stateless data), continue -
    template = init_state(model, opt, jax.random.PRNGKey(0))
    state_b, step0, _ = restore(latest_step(tmp_path), template)
    assert step0 == 4
    for i in range(4, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state_b, _ = step_fn(state_b, batch)
    _, m_b = step_fn(state_b, batch6)
    # deterministic resume: identical trajectory
    np.testing.assert_allclose(loss_a, float(m_b["loss"]), rtol=1e-5)

    # --- serve from the trained params ------------------------------------
    out, rep = greedy_generate(model, state_b.params,
                               jnp.ones((2, 8), jnp.int32), steps=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))


def test_efta_protects_model_level_fault():
    """A soft error injected into a model's attention is corrected end-to-end:
    logits with FT+fault match the clean run; with FT off they do not."""
    from repro.models.attention import attn_apply
    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    fault = FaultSpec.single(Site.GEMM2, block=0, batch=0, head=1, row=3,
                             col=2, bit=27)
    blk = jax.tree.map(lambda t: t[0], params["blocks"])
    clean, _, _ = attn_apply(blk["attn"], x, acfg=cfg.attn, ft=cfg.ft)
    prot, rep, _ = attn_apply(blk["attn"], x, acfg=cfg.attn, ft=cfg.ft,
                              fault=fault)
    np.testing.assert_allclose(prot, clean, atol=1e-4)
    assert int(rep.detected.sum()) >= 1
    off = dataclasses.replace(cfg.ft, mode="off")
    bad, _, _ = attn_apply(blk["attn"], x, acfg=cfg.attn, ft=off, fault=fault)
    assert float(jnp.max(jnp.abs(bad - clean))) > 1e-3
