"""Version-compatibility shims for the pinned jax.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg ``check_rep`` -> ``check_vma`` along
the way; this wrapper presents the new-style interface on either version.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
