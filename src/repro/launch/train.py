"""Training launcher: EFTA-protected LM training with the full FT runtime
(async checkpoints, straggler monitor, fault-rate escalation, resume).

CPU-scale demo:
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-smoke \
      --steps 60 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 20

Production notes: on a pod this runs under the 16x16 / 2x16x16 mesh from
launch/mesh.py (pass --mesh pod|multipod); XLA's latency-hiding scheduler is
enabled for compute/comm overlap via --xla-lhs.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_pipeline
from repro.ft_runtime import (AsyncCheckpointer, FaultRateMonitor,
                              StragglerMonitor, latest_step, restore)
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import TrainState, init_state, make_train_step
from repro.utils import get_logger

LHS_FLAGS = ("--xla_tpu_enable_latency_hiding_scheduler=true "
             "--xla_tpu_megacore_fusion_allow_ags=true")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["none", "pod", "multipod"],
                    default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    log = get_logger("train")

    cfg = get_config(args.arch)
    model = build_model(cfg)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    opt = AdamW(lr=warmup_cosine(args.lr, warmup=10, total=args.steps))
    state = init_state(model, opt, jax.random.PRNGKey(args.seed))
    start_step = 0
    if args.resume and args.ckpt_dir:
        ck = latest_step(args.ckpt_dir)
        if ck is not None:
            state, start_step, _ = restore(ck, state)
            log.info("resumed from %s at step %d", ck, start_step)

    step_fn = jax.jit(make_train_step(model, opt, mesh=mesh,
                                      microbatches=args.microbatches),
                      donate_argnums=(0,))
    data = make_pipeline(cfg, global_batch=args.batch, seq_len=args.seq,
                         seed=args.seed)
    ckpt = AsyncCheckpointer()
    straggler = StragglerMonitor()
    faults = FaultRateMonitor()

    for step in range(start_step, args.steps):
        straggler.step_start()
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        v = straggler.step_end()
        status = faults.observe(int(np.sum(np.asarray(
            metrics["ft_detected"]))))
        if status == "cordon":
            log.warning("sustained EFTA fault rate: cordon + elastic restart "
                        "advised (see ft_runtime.elastic)")
        if v.is_straggler:
            log.warning("straggler step %d: %.3fs (median %.3fs)", step,
                        v.step_time, v.median)
        if step % args.log_every == 0 or step == args.steps - 1:
            log.info("step %4d loss %.4f ce %.4f ft=%s %.3fs/step", step,
                     float(metrics["loss"]), float(metrics["ce"]),
                     np.asarray(metrics["ft_detected"]).tolist(), v.step_time)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(os.path.join(args.ckpt_dir, f"step_{step+1}"),
                            state, step=step + 1)
    ckpt.wait()
    log.info("done: %d steps", args.steps - start_step)


if __name__ == "__main__":
    main()
