"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384e top-8 + 1 shared expert, expert d_ff=2048 (assignment spec).
head_dim = 7168/64 = 112 (not 128-aligned; padding waste quantified in
roofline). [arXiv:2501.kimi2; unverified]"""
from repro.configs.base import AttnCfg, FTCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, d_ff=2048, vocab_size=163840,
    attn=AttnCfg(num_heads=64, num_kv_heads=8, head_dim=112),
    moe=MoECfg(num_experts=384, top_k=8, expert_d_ff=2048,
               num_shared_experts=1, shared_d_ff=2048),
    source="arXiv:2501.kimi2",
)
