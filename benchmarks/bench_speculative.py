"""Speculative decoding (propose→score→accept) vs plain unified decode.

Measures the payoff of scoring K draft tokens per request in ONE
EFTA-protected chunked launch: accepted-tokens-per-step and end-to-end
decode throughput against the non-speculative engine, across proposers that
span the acceptance-rate axis

  * ``ngram``       — self-drafting prompt lookup on a repetitive-suffix
                      workload (the regime speculation targets: code,
                      templated text, self-consistency replays)
  * ``draft/self``  — the serving model drafting for itself (acceptance ~1:
                      the upper bound; every step commits K + 1 tokens)
  * ``draft/cold``  — a freshly-initialized draft model (acceptance ~0:
                      the overhead floor — every step still commits one
                      token, the engine degenerates gracefully)

All engines must be token-identical (greedy parity oracle) — speculation
changes throughput, never tokens. On CPU the absolute wall-clock mixes in
interpreter overhead; accepted-tokens/step is the hardware-relevant number
(each accepted draft removes one full serial decode launch).

  PYTHONPATH=src python -m benchmarks.bench_speculative
  PYTHONPATH=src python -m benchmarks.bench_speculative --smoke

``--smoke`` runs the tiny configuration and asserts: greedy speculative
output is token-identical to the non-speculative engine on both backends,
the ngram proposer clears > 1 accepted-token/step on the repetitive
workload, and the fused engine still compiled at most two step programs
with speculation on (the propose→score→accept refactor pads draft K to the
chunk width instead of adding shapes) — the CI guard for dispatch or
compile-count regressions.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _engine(model, params, **kw):
    from repro.serve import PagedServeEngine
    return PagedServeEngine(model, params, **kw)


def _drive(eng, prompts, gen):
    for p in prompts:
        eng.submit(p, max_new_tokens=gen)
    t0 = time.perf_counter()
    outs = eng.run()
    return time.perf_counter() - t0, outs


def _compiled_programs(eng) -> int:
    fn = getattr(eng, "_step_fused", None)
    try:
        return int(fn._cache_size())
    except (AttributeError, TypeError):
        return -1


def run(smoke: bool = False) -> None:
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cold_params = model.init(jax.random.PRNGKey(7))
    rng = np.random.default_rng(0)

    n_slots, cache_len, bs, chunk = (2, 96, 16, 16) if smoke \
        else (4, 192, 16, 16)
    n_req, gen, K = (3, 24, 4) if smoke else (6, 48, 4)
    # repetitive-suffix workload: prompts built from a short repeated
    # pattern, so the tail n-gram always has an earlier occurrence and the
    # greedy continuation settles into loops the proposer can read
    prompts = []
    for _ in range(n_req):
        pat = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
        reps = int(rng.integers(4, 7))
        prompts.append(np.tile(pat, reps))

    variants = {
        "baseline": dict(),
        "ngram": dict(speculate="ngram", draft_len=K),
        "draft/self": dict(speculate="draft", draft_len=K,
                           draft_model=model, draft_params=params),
        "draft/cold": dict(speculate="draft", draft_len=K,
                           draft_model=model, draft_params=cold_params),
    }
    results, streams, engines = {}, {}, {}
    for kernel in ("fused", "gather"):
        for name, kw in variants.items():
            if kernel == "gather" and name.startswith("draft"):
                continue        # the acceptance axis is covered on fused
            tag = f"{kernel}/{name}"
            eng = _engine(model, params, n_slots=n_slots,
                          cache_len=cache_len, block_size=bs,
                          chunk_size=chunk, kernel=kernel, **kw)
            _drive(eng, prompts, gen)              # warmup: compiles
            tok0, step0 = eng.stats.tokens, eng.stats.steps
            dt, outs = _drive(eng, prompts, gen)
            results[tag] = (dt, eng.stats.tokens - tok0,
                            eng.stats.steps - step0,
                            eng.acceptance_rate, eng.paged_stats)
            streams[tag] = [list(outs[r]) for r in sorted(outs)]
            engines[tag] = eng

    ref = streams["fused/baseline"]
    for tag, got in streams.items():
        assert got == ref, f"{tag} diverged from fused/baseline: " \
                           f"{got} != {ref}"

    print(f"speculative decoding ({'smoke' if smoke else 'full'}; {n_req} "
          f"repetitive prompts x {gen} tokens, K={K}, chunk={chunk}):")
    base_dt = {k: results[f"{k}/baseline"][0] for k in ("fused", "gather")}
    tok_per_step = {}
    for tag, (dt, tokens, steps, rate, ps) in results.items():
        kernel = tag.split("/")[0]
        tps = tokens / dt
        tok_per_step[tag] = tokens / max(steps, 1)
        print(f"  {tag:18s} {tps:8.1f} tok/s ({base_dt[kernel] / dt:4.2f}x "
              f"vs baseline)   tokens/step={tokens / max(steps, 1):5.2f}   "
              f"acceptance={rate:4.2f}   rolled-back rows="
              f"{ps.spec_rolled_back_rows}")
    fused_programs = _compiled_programs(engines["fused/ngram"])
    print(f"  fused step programs compiled with speculation on: "
          f"{fused_programs} (<= 2: chunk width + decode width)")
    if smoke:
        # strict: an unreadable cache size (-1: the private jax API moved)
        # must fail the guard loudly, not silently disarm it
        assert fused_programs in (1, 2), \
            f"speculation broke the compile-count invariant (or the " \
            f"program-count probe broke): {fused_programs} programs"
        for k in ("fused", "gather"):
            ps = results[f"{k}/ngram"][4]
            per_spec_step = 1 + ps.spec_accepted_tokens / max(ps.spec_steps,
                                                              1)
            assert ps.spec_steps > 0 and per_spec_step > 1.0, \
                f"{k}/ngram accepted no drafts on the repetitive " \
                f"workload: {per_spec_step:.2f} accepted-tokens/step"
            assert tok_per_step[f"{k}/ngram"] > \
                tok_per_step[f"{k}/baseline"], \
                f"{k}/ngram did not raise tokens/step over baseline"
        assert results["fused/draft/self"][3] > 0.9, \
            "self-draft acceptance should be ~1"
        print("SMOKE OK: speculative decoding token-identical, "
              "accepted-tokens/step > 1, <= 2 compiled programs")


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
