"""Paper Fig. 10: fault-tolerance overhead breakdown inside EFTA.

Components measured by differencing: plain flash (mode=off), +ABFT-GEMM
checksums (detect, softmax checks disabled via paper-mode flags), +SNVR,
+correction (full)."""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, qkv, time_fn
from repro.core import EFTAConfig
from repro.core.efta import efta_attention

B, H, S, D = 4, 4, 512, 64


def t(cfg, q, k, v):
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    return time_fn(lambda: fn(q, k, v))


def run():
    q, k, v = qkv(B, H, H, S, D, jnp.float32)
    base = t(EFTAConfig(mode="off", block_kv=128), q, k, v)
    detect = t(EFTAConfig(mode="detect", stride=16, block_kv=128,
                          shadow_rowsum=False, shadow_rowmax=False), q, k, v)
    snvr = t(EFTAConfig(mode="detect", stride=16, block_kv=128), q, k, v)
    full = t(EFTAConfig(mode="correct", stride=16, block_kv=128), q, k, v)
    rows = [
        {"name": "flash_no_ft", "us": base * 1e6, "derived": "baseline"},
        {"name": "abft_checksums", "us": detect * 1e6,
         "derived": f"+{(detect-base)/base*100:.1f}%"},
        {"name": "abft+snvr", "us": snvr * 1e6,
         "derived": f"+{(snvr-base)/base*100:.1f}%"},
        {"name": "full_correct", "us": full * 1e6,
         "derived": f"+{(full-base)/base*100:.1f}%"},
    ]
    emit(rows, "Fig10: EFTA FT overhead breakdown")
    return rows


if __name__ == "__main__":
    run()
