"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536 —
Finch: data-dependent decay. EFTA inapplicable (no attention GEMMs); time-mix
projections protected by ABFT-GEMM (DESIGN.md §Arch-applicability).
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
    attn=None,
    ssm=SSMCfg(kind="rwkv6", head_dim=64),
    source="arXiv:2404.05892",
)
