"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import AttnCfg, FTCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, d_ff=4864, vocab_size=32000,
    attn=AttnCfg(num_heads=56, num_kv_heads=8, head_dim=128),
    moe=MoECfg(num_experts=128, top_k=2, expert_d_ff=4864, dense_d_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base",
)
