"""SSM mixers: scan vs stepwise equivalence (the serving invariant)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMCfg
from repro.models import ssm as S
import pytest

pytestmark = pytest.mark.quick


def test_mamba_seq_vs_full():
    cfg = SSMCfg(kind="mamba", state_dim=8, expand=2)
    params = S.mamba_init(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 16))
    y_full, _ = S.mamba_apply(params, x, cfg)
    st = None
    ys = []
    y, st = S.mamba_apply(params, x[:, :6], cfg, state=st)
    ys.append(y)
    for t in range(6, 10):
        y, st = S.mamba_apply(params, x[:, t:t + 1], cfg, state=st)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_full, atol=1e-5)


def test_rwkv_time_mix_seq_vs_full():
    cfg = SSMCfg(kind="rwkv6", head_dim=8)
    d = 16
    params = S.rwkv6_init(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d))
    y_full, _ = S.rwkv6_time_mix(
        params, x, cfg, state=S.rwkv_state_init(2, d, cfg, jnp.float32))
    st = S.rwkv_state_init(2, d, cfg, jnp.float32)
    ys = []
    y, st = S.rwkv6_time_mix(params, x[:, :5], cfg, state=st)
    ys.append(y)
    for t in range(5, 10):
        y, st = S.rwkv6_time_mix(params, x[:, t:t + 1], cfg, state=st)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_full, atol=1e-5)


def test_rwkv_decay_in_range():
    cfg = SSMCfg(kind="rwkv6", head_dim=8)
    params = S.rwkv6_init(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16)) * 3
    y, st = S.rwkv6_time_mix(
        params, x, cfg, state=S.rwkv_state_init(1, 16, cfg, jnp.float32))
    assert bool(jnp.all(jnp.isfinite(st.wkv)))  # decay in (0,1): stable state
