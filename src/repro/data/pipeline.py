"""Deterministic synthetic LM data pipeline.

Stateless-by-construction: batch ``i`` is a pure function of (seed, i), so
fault-tolerant resume needs only the step counter from the checkpoint — no
data-iterator state to snapshot, no skew after elastic re-scaling (the global
batch is re-sharded by the mesh, not by the pipeline).

The token stream is a mixture of structured sources (repeats, arithmetic-ish
progressions, markov chains) so tiny models show a real, decreasing loss.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0     # vlm/audio stub embeddings
    d_model: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        kind = rng.choice([0, 0, 1, 2], size=(b,))  # repeats dominate: learnable fast
        toks = np.empty((b, s + 1), np.int32)
        for i in range(b):
            if kind[i] == 0:      # period-k repeats
                k = int(rng.integers(2, 8))
                base = rng.integers(0, v, size=(k,))
                toks[i] = np.resize(base, s + 1)
            elif kind[i] == 1:    # affine progression mod v
                a = int(rng.integers(1, 7))
                c = int(rng.integers(0, v))
                toks[i] = (c + a * np.arange(s + 1)) % v
            else:                 # 2-gram markov with few states
                states = rng.integers(0, v, size=(16,))
                idx = rng.integers(0, 16, size=(s + 1,))
                idx = np.maximum.accumulate(idx) % 16
                toks[i] = states[idx]
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (b, cfg.frontend_tokens, cfg.d_model)).astype(np.float32) * 0.1
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_pipeline(model_cfg, *, global_batch: int, seq_len: int,
                  seed: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed,
        frontend_tokens=model_cfg.frontend_tokens
        if model_cfg.family in ("vlm", "audio") else 0,
        d_model=model_cfg.d_model))
