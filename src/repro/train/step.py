"""Train-step factory: remat, microbatch gradient accumulation, FSDP/TP
sharding, and optional compressed cross-pod gradient sync.

``pod_sync``:
  * "dense"   — one global jit; GSPMD reduces gradients over all DP axes
                (pod included) in full precision.
  * "int8_ef" — shard_map over the ``pod`` axis: in-pod reduction stays full
                precision (fast ICI), the cross-pod hop carries int8 with
                error feedback (distributed-optimization trick; 4x fewer
                cross-DCN bytes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.collectives import compressed_psum
from repro.distributed.sharding import dp_axes
from repro.models.api import Model
from repro.optim.adamw import AdamW
from repro.train.state import TrainState
from repro.utils.compat import shard_map


def init_state(model: Model, optimizer: AdamW, rng, *, pod_sync="dense"):
    params = model.init(rng)
    ef = None
    if pod_sync == "int8_ef":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def make_train_step(model: Model, optimizer: AdamW, *, mesh=None,
                    microbatches: int = 1, pod_sync: str = "dense"):
    """Returns step(state, batch) -> (state, metrics). batch leaves are
    (global_batch, ...) arrays sharded over the DP axes."""

    def loss_fn(params, batch):
        return model.loss(params, batch, mesh=mesh)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # gradient accumulation: scan over microbatch slices
        def mb(carry, mb_batch):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb_batch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics
        split = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]), batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), metrics = jax.lax.scan(mb, (zeros, jnp.float32(0)),
                                              split)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss / microbatches, metrics, grads

    if pod_sync == "int8_ef" and mesh is not None and "pod" in mesh.shape \
            and mesh.shape["pod"] > 1:
        def step(state: TrainState, batch):
            def per_pod(params, batch_l, ef):
                loss, metrics, grads = grads_of(params, batch_l)
                # cross-pod gradient mean: int8 + error feedback
                flat_g, tdef = jax.tree_util.tree_flatten(grads)
                flat_e = tdef.flatten_up_to(ef)
                out_g, out_e = [], []
                for g, e in zip(flat_g, flat_e):
                    gm, ne = compressed_psum(g, "pod", e)
                    out_g.append(gm)
                    out_e.append(ne)
                grads = tdef.unflatten(out_g)
                new_ef = tdef.unflatten(out_e)
                loss = jax.lax.pmean(loss, "pod")
                return grads, new_ef, loss, metrics

            grads, new_ef, loss, metrics = shard_map(
                per_pod, mesh=mesh,
                in_specs=(P(), P("pod"), P()),
                out_specs=(P(), P(), P(), P()),
                check_vma=False,
            )(state.params, batch, state.ef)
            new_params, new_opt = optimizer.update(grads, state.opt,
                                                   state.params)
            return TrainState(new_params, new_opt, state.step + 1,
                              new_ef), metrics
        return step

    def step(state: TrainState, batch):
        loss, metrics, grads = grads_of(state.params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        return TrainState(new_params, new_opt, state.step + 1,
                          state.ef), metrics
    return step
