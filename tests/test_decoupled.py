"""Decoupled baseline (paper §3.1): correctness + memory accounting."""
import jax.numpy as jnp
import jax
import numpy as np

import pytest

from repro.core import (FaultSpec, Site, decoupled_ft_attention,
                        decoupled_memory_bytes, reference_attention)

pytestmark = pytest.mark.quick


def test_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    out, rep = decoupled_ft_attention(q, k, v)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=1e-5)
    assert int(rep.detected.sum()) == 0


def test_fault_corrected():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jax.random.normal(ks[2], (1, 2, 32, 16))
    ref = reference_attention(q, k, v)
    f = FaultSpec.single(Site.GEMM1, row=3, col=7, bit=27)
    out, rep = decoupled_ft_attention(q, k, v, fault=f)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


def test_quadratic_memory_accounting():
    # paper Fig 9: decoupled stores S and P in HBM -> OOM at 16k on A100-40GB
    b, h = 1, 16
    at_16k = decoupled_memory_bytes(b * 16, h, 1024, 1024)  # 16k tokens total
    assert decoupled_memory_bytes(1, 32, 16384, 16384) > 30e9  # OOM regime
