"""Paged KV-cache subsystem: host-side block/prefix bookkeeping, token
identity of the paged engine against the ring engine, read-time checksum
detection of resident KV corruption with block re-prefill repair, prefix-
cache hit/miss token identity, and eviction/preemption under pool pressure."""
import numpy as np
import pytest

from repro.serve.blocks import NULL_BLOCK, BlockPool, PrefixCache, chain_hash

pytestmark = pytest.mark.quick


# ---------------------------------------------------------------------------
# host-side bookkeeping (no jax)
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_refcounts():
    pool = BlockPool(3, 4)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert sorted((a, b, c)) == [1, 2, 3]       # 0 is the null block
    assert NULL_BLOCK not in (a, b, c)
    assert pool.alloc() is None                 # exhausted, nothing evictable
    pool.ref_inc(a)
    assert pool.ref_of(a) == 2 and pool.is_shared(a)
    pool.ref_dec(a)
    assert pool.ref_of(a) == 1 and not pool.is_shared(a)
    pool.ref_dec(b)
    assert pool.free_blocks == 1
    d = pool.alloc()
    assert d == b                               # freed id is reusable
    pool.ref_dec(a)
    pool.ref_dec(c)
    pool.ref_dec(d)
    with pytest.raises(ValueError):
        pool.ref_dec(d)                         # double free


def test_block_pool_cow_splits_shared_blocks():
    pool = BlockPool(4, 4)
    a = pool.alloc()
    # private block: write-through, no copy
    assert pool.cow(a) == (a, False)
    pool.ref_inc(a)                             # second holder
    wb, needs_copy = pool.cow(a)
    assert needs_copy and wb not in (a, NULL_BLOCK)
    assert pool.ref_of(a) == 1 and pool.ref_of(wb) == 1
    assert pool.stats.cow_copies == 1
    # registered (prefix-cached) blocks also require COW even at ref == 1
    b = pool.alloc()
    pool.register(b, chain_hash(None, (1, 2, 3, 4)))
    assert pool.is_shared(b)
    wb2, needs_copy2 = pool.cow(b)
    assert needs_copy2 and wb2 != b


def test_block_pool_parks_and_evicts_cached_blocks_lru():
    evicted = []
    pool = BlockPool(2, 4)
    pool.on_evict = lambda bid, h: evicted.append((bid, h))
    a, b = pool.alloc(), pool.alloc()
    ha, hb = chain_hash(None, (1,) * 4), chain_hash(None, (2,) * 4)
    pool.register(a, ha)
    pool.register(b, hb)
    pool.ref_dec(a)                             # parked, evictable
    pool.ref_dec(b)
    assert pool.free_blocks == 2
    pool.touch(a)                               # refresh a: b is now LRU...
    # (a was parked first; touch moves it to MRU, so b is still newer)
    c = pool.alloc()                            # pressure: evict LRU
    assert c == b or c == a
    assert evicted and evicted[0][1] in (ha, hb)
    assert pool.stats.evictions == 1


def test_prefix_cache_match_and_insert_roundtrip():
    pool = BlockPool(8, 4)
    pc = PrefixCache(pool)
    tokens = list(range(10))                    # 2 full blocks + partial
    bids = [pool.alloc() for _ in range(3)]
    pc.insert(tokens, bids)
    assert pc.cached_blocks == 2                # only full blocks registered
    assert pc.match(tokens) == bids[:2]
    assert pc.match(tokens[:7]) == bids[:1]     # one full block covered
    assert pc.match([9] + tokens[1:]) == []     # first block differs -> miss
    # divergence after the first block stops the chain
    assert pc.match(tokens[:4] + [99] * 6) == bids[:1]
    # max_blocks caps the hit length
    assert pc.match(tokens, max_blocks=1) == bids[:1]


def test_prefix_cache_hash_collision_degrades_to_miss():
    """Token identity is re-verified on every hit: a poisoned hash entry
    (simulated collision) must read as a miss, never as a wrong prefix."""
    pool = BlockPool(8, 4)
    pc = PrefixCache(pool)
    tokens = [1, 2, 3, 4]
    bids = [pool.alloc()]
    pc.insert(tokens, bids)
    # graft the existing entry under the hash of *different* tokens
    other = [5, 6, 7, 8]
    pc._by_hash[chain_hash(None, tuple(other))] = \
        pc._by_hash[chain_hash(None, tuple(tokens))]
    assert pc.match(other) == []
    assert pc.stats.collisions == 1


def test_prefix_cache_forgets_evicted_blocks():
    pool = BlockPool(2, 4)
    pc = PrefixCache(pool)
    tokens = [1, 2, 3, 4, 5, 6, 7, 8]
    bids = [pool.alloc(), pool.alloc()]
    pc.insert(tokens, bids)
    for b in bids:
        pool.ref_dec(b)                         # parked
    assert pc.match(tokens) == bids
    new = pool.alloc()                          # evicts bids[0] (LRU)
    assert new == bids[0]
    assert pc.match(tokens) == []               # chain broken at block 0
    assert pc.cached_blocks == 1


# ---------------------------------------------------------------------------
# engine-level (jax; gpt2-smoke)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    return cfg, model, params, rng


def _paged(model, params, **kw):
    from repro.serve import PagedServeEngine
    kw.setdefault("n_slots", 3)
    kw.setdefault("cache_len", 48)
    kw.setdefault("block_size", 16)
    return PagedServeEngine(model, params, **kw)


def test_paged_engine_token_identical_to_ring_engine(setup):
    """The acceptance bar: mixed-length prompts, more requests than slots
    (staggered admission + slot reuse), greedy sampling — the paged engine's
    tokens must equal the ring engine's exactly."""
    from repro.serve import ServeEngine
    cfg, model, params, rng = setup
    lengths = [5, 9, 16, 3, 12, 7]
    steps = [6, 4, 8, 5, 3, 7]
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in lengths]
    ring = ServeEngine(model, params, n_slots=3, cache_len=48)
    paged = _paged(model, params)
    for p, s in zip(prompts, steps):
        ring.submit(p, max_new_tokens=s)
        paged.submit(p, max_new_tokens=s)
    ref = ring.run()
    got = paged.run()
    assert set(got) == set(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid], err_msg=f"rid={rid}")
    assert paged.stats.steps < sum(steps)       # actually batched
    assert paged.paged_stats.kv_detected_blocks == 0  # no false positives


def test_paged_stochastic_sampling_matches_ring(setup):
    """Per-request PRNG streams are position-keyed, not cache-layout-keyed:
    stochastic sampling must agree between paged and ring engines."""
    from repro.serve import SamplingParams, ServeEngine
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (11,)).astype(np.int32)
    sp = SamplingParams(temperature=1.3, top_k=17, seed=5)
    ring = ServeEngine(model, params, n_slots=2, cache_len=48)
    paged = _paged(model, params, n_slots=2)
    r0 = ring.submit(prompt, max_new_tokens=7, sampling=sp)
    r1 = paged.submit(prompt, max_new_tokens=7, sampling=sp)
    np.testing.assert_array_equal(paged.run()[r1], ring.run()[r0])


def test_prefix_cache_prefill_once_and_token_identity(setup):
    """Two requests sharing a 2-block system prompt: the second admission
    must hit the prefix cache (prefilling only its suffix) and still produce
    exactly the tokens a cold engine produces."""
    cfg, model, params, rng = setup
    sys_prompt = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
             for n in (5, 7)]
    prompts = [np.concatenate([sys_prompt, t]) for t in tails]

    eng = _paged(model, params, cache_len=64, num_blocks=16)
    r0 = eng.submit(prompts[0], max_new_tokens=4)
    eng.run()
    r1 = eng.submit(prompts[1], max_new_tokens=4)  # prefix now resident
    out1 = eng.run()[r1]
    assert eng.pool.prefix.stats.hit_tokens >= 32

    cold = _paged(model, params, cache_len=64, num_blocks=16)
    rc = cold.submit(prompts[1], max_new_tokens=4)
    np.testing.assert_array_equal(out1, cold.run()[rc])
    assert cold.pool.prefix.stats.hit_tokens == 0


def test_kv_bit_flip_detected_repaired_and_reported(setup):
    """A resident-state SEU between decode steps: detected at the next
    gather by the block checksums, repaired by re-prefilling only that
    block, reported at telemetry site 6 — and the final tokens equal an
    uncorrupted run's."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)

    clean = _paged(model, params, n_slots=2)
    rc = clean.submit(prompt, max_new_tokens=8)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2)
    rid = eng.submit(prompt, max_new_tokens=8)
    eng.step()
    req = list(eng.scheduler.active_rows())[0]
    eng.inject_kv_fault(layer=1, block=req.block_ids[0], head=0, row=3,
                        col=5, bit=27, into="v")
    out = eng.run()[rid]

    np.testing.assert_array_equal(out, ref)
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks == 1
    st = eng.telemetry.requests[rid]
    assert st.detected[5] == 1 and st.corrected[5] == 1
    assert eng.telemetry.summary()["detected"] >= 1


def test_kv_repair_survives_zero_retry_budget(setup):
    """Regression: with ``max_retries=0`` the engine must still refuse to
    commit an attempt that read poisoned KV — otherwise the corrupted tail
    append refreshes the block checksums over bad data and the corruption
    goes permanently silent. KV repair has its own >= 1 retry budget."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (18,)).astype(np.int32)

    clean = _paged(model, params, n_slots=2, max_retries=0)
    rc = clean.submit(prompt, max_new_tokens=6)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, max_retries=0)
    rid = eng.submit(prompt, max_new_tokens=6)
    eng.step()
    req = list(eng.scheduler.active_rows())[0]
    eng.inject_kv_fault(layer=0, block=req.block_ids[1], head=1, row=1,
                        col=2, bit=26, into="k")
    out = eng.run()[rid]
    np.testing.assert_array_equal(out, ref)
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks == 1


def test_persistent_kv_corruption_never_commits(setup):
    """A block that stays corrupted through re-prefill (failing memory, not
    a transient SEU — simulated by defeating the repair) must never have a
    poisoned-gather attempt committed, and repeated poisoned steps must
    escalate to a hard error instead of spinning."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    eng = _paged(model, params, n_slots=2)
    rid = eng.submit(prompt, max_new_tokens=6)
    eng.step()
    req = list(eng.scheduler.active_rows())[0]
    eng.inject_kv_fault(layer=0, block=req.block_ids[0], head=0, row=1,
                        col=1, bit=28, into="k")
    eng._repair_blocks = lambda *a, **k: None     # sticky: repair defeated
    n_before = req.num_generated
    eng.step()
    assert req.num_generated == n_before          # nothing committed
    assert eng.paged_stats.kv_detected_blocks == 1  # deduped across retries
    assert eng.telemetry.requests[rid].detected[5] >= 1
    with pytest.raises(RuntimeError, match="cordon"):
        eng.run()


def test_kv_campaign_no_silent_resident_corruption(setup):
    """Randomized resident-KV campaign: every high-bit flip must be caught
    at read time and healed without changing any request's tokens."""
    from repro.core import run_kv_campaign
    r = run_kv_campaign(n_trials=6, seed=3)
    assert r.n_trials == 6
    assert r.detected == 6, r.format_table()
    assert r.repaired_blocks >= 6
    assert r.mismatched_requests == 0, r.format_table()
    assert r.telemetry_kv_detected == 6


def test_pool_pressure_preempts_and_evicts_yet_stays_exact(setup):
    """Decode growth outruns a deliberately tiny block pool: the engine must
    preempt (freeing blocks), resume the victim later, and still match the
    ring engine token-for-token."""
    from repro.serve import ServeEngine
    cfg, model, params, rng = setup
    pa = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    eng = _paged(model, params, n_slots=2, cache_len=32, block_size=8,
                 num_blocks=5)
    ra = eng.submit(pa, max_new_tokens=12)
    rb = eng.submit(pb, max_new_tokens=12)
    outs = eng.run()
    assert eng.paged_stats.preemptions >= 1

    ring = ServeEngine(model, params, n_slots=2, cache_len=32)
    r2a = ring.submit(pa, max_new_tokens=12)
    r2b = ring.submit(pb, max_new_tokens=12)
    ref = ring.run()
    np.testing.assert_array_equal(outs[ra], ref[r2a])
    np.testing.assert_array_equal(outs[rb], ref[r2b])


def test_fused_backend_token_identical_to_ring_engine(setup):
    """The fused-kernel acceptance bar: mixed-length prompts, more requests
    than slots, staggered admission and slot reuse — the fused block-table
    backend (no contiguous gather, natively batched ragged decode) must
    produce exactly the ring engine's tokens."""
    from repro.serve import ServeEngine
    cfg, model, params, rng = setup
    lengths = [5, 9, 16, 3, 12]
    steps = [6, 4, 8, 5, 3]
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in lengths]
    ring = ServeEngine(model, params, n_slots=3, cache_len=48)
    fused = _paged(model, params, kernel="fused")
    for p, s in zip(prompts, steps):
        ring.submit(p, max_new_tokens=s)
        fused.submit(p, max_new_tokens=s)
    ref = ring.run()
    got = fused.run()
    assert set(got) == set(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid], err_msg=f"rid={rid}")
    assert fused.stats.steps < sum(steps)          # actually batched
    assert fused.paged_stats.kv_detected_blocks == 0   # no false positives


def test_fused_backend_exact_under_preemption_and_eviction(setup):
    """Decode growth outruns a tiny block pool on the fused backend: COW
    splits, preemption, resume-from-prefix — still token-identical to the
    ring engine (the ISSUE's end-to-end serve bar)."""
    from repro.serve import ServeEngine
    cfg, model, params, rng = setup
    pa = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    eng = _paged(model, params, n_slots=2, cache_len=32, block_size=8,
                 num_blocks=5, kernel="fused")
    ra = eng.submit(pa, max_new_tokens=12)
    rb = eng.submit(pb, max_new_tokens=12)
    outs = eng.run()
    assert eng.paged_stats.preemptions >= 1

    ring = ServeEngine(model, params, n_slots=2, cache_len=32)
    r2a = ring.submit(pa, max_new_tokens=12)
    r2b = ring.submit(pb, max_new_tokens=12)
    ref = ring.run()
    np.testing.assert_array_equal(outs[ra], ref[r2a])
    np.testing.assert_array_equal(outs[rb], ref[r2b])


def test_fused_backend_detects_and_repairs_kv_flip(setup):
    """Resident SEU on the fused backend: the kernel's in-loop verify flags
    the block in the same pass that streams it; the engine re-prefills only
    that block, retries, and finishes token-identical."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)
    clean = _paged(model, params, n_slots=2, kernel="fused")
    rc = clean.submit(prompt, max_new_tokens=8)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, kernel="fused")
    rid = eng.submit(prompt, max_new_tokens=8)
    eng.step()
    req = list(eng.scheduler.active_rows())[0]
    eng.inject_kv_fault(layer=1, block=req.block_ids[0], head=0, row=3,
                        col=5, bit=27, into="v")
    out = eng.run()[rid]
    np.testing.assert_array_equal(out, ref)
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks == 1
    st = eng.telemetry.requests[rid]
    assert st.detected[5] == 1 and st.corrected[5] == 1


def test_fused_backend_corrects_in_compute_seu(setup):
    """EFTA compute-site SEUs on the fused backend: the engine's per-slot
    FaultSpec batch translates to the kernel's descriptor, the SEU is
    corrected in-kernel (or retried), telemetry sees it, and the tokens
    match a clean run."""
    from repro.core import FaultSpec, Site
    from repro.serve import batch_faults
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (18,)).astype(np.int32)

    clean = _paged(model, params, n_slots=2, kernel="fused")
    rc = clean.submit(prompt, max_new_tokens=6)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, kernel="fused")
    rid = eng.submit(prompt, max_new_tokens=6)
    spec = FaultSpec.single(Site.GEMM2, block=0, head=1, row=0, col=3,
                            bit=27)
    faults = {2: batch_faults(2, {0: spec}),
              4: batch_faults(2, {0: FaultSpec.single(
                  Site.GEMM1, block=1, head=2, row=0, col=5, bit=26)})}
    out = eng.run(faults_by_step=faults)[rid]
    np.testing.assert_array_equal(out, ref)
    st = eng.telemetry.requests[rid]
    assert sum(st.detected[:5]) >= 1
    assert st.detected[5] == 0          # compute faults, not memory faults


def test_stamped_verification_skips_untouched_blocks_and_stays_exact(setup):
    """Generation-stamped read-time verification (gather backend): blocks
    untouched since their last verified read skip the checksum fold; stamps
    invalidate on write (the tail append) and on repair; the clean-run
    tokens are identical to the always-verify engine's."""
    cfg, model, params, rng = setup
    lengths = [5, 9, 16, 3]
    steps = [6, 4, 8, 5]
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in lengths]
    always = _paged(model, params)
    stamped = _paged(model, params, kv_verify="stamped")
    for p, s in zip(prompts, steps):
        always.submit(p, max_new_tokens=s)
        stamped.submit(p, max_new_tokens=s)
    ref = always.run()
    got = stamped.run()
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid], err_msg=f"rid={rid}")
    # the whole point: strictly fewer checksum folds, none skipped under
    # the always policy
    assert stamped.paged_stats.kv_verify_skips > 0
    assert always.paged_stats.kv_verify_skips == 0
    assert stamped.paged_stats.kv_verified_blocks < \
        always.paged_stats.kv_verified_blocks


def test_stamps_invalidate_on_write_and_on_repair(setup):
    """The regression contract: a committed verify stamps the blocks it
    folded; the decode append invalidates the tail's stamp; a detected
    corruption's repair rewrites the block and invalidates again (so the
    next read re-verifies the healed content)."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)
    clean = _paged(model, params, n_slots=2, kv_verify="stamped")
    rc = clean.submit(prompt, max_new_tokens=8)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, kv_verify="stamped")
    rid = eng.submit(prompt, max_new_tokens=8)
    eng.step()
    req = list(eng.scheduler.active_rows())[0]
    blocks = eng.pool.blocks
    tail_j = int(eng._pos[req.slot]) // eng.block_size
    # after the committed step: non-tail blocks are stamped verified, the
    # tail was appended to (write -> stamp invalid)
    assert not blocks.needs_verify(req.block_ids[0])
    assert blocks.needs_verify(req.block_ids[tail_j])

    # corrupt the TAIL block (stamped-invalid, so still re-verified): must
    # be detected, repaired, and the repair must invalidate the stamp again
    eng.inject_kv_fault(layer=0, block=req.block_ids[tail_j], head=1,
                        row=1, col=2, bit=27, into="k")
    eng.step()
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks >= 1
    out = eng.run()[rid]
    np.testing.assert_array_equal(out, ref)


def test_stamped_policy_defers_detection_of_stamped_blocks(setup):
    """The documented coverage tradeoff, pinned: under the stamped policy a
    flip landing in a verified-and-untouched block is *not* re-folded (the
    skip is the throughput win); the always policy catches the identical
    flip immediately. Anyone weakening the default `always` policy must
    confront this test."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)

    def poisoned(**kw):
        eng = _paged(model, params, n_slots=2, **kw)
        eng.submit(prompt, max_new_tokens=4)
        eng.step()
        req = list(eng.scheduler.active_rows())[0]
        # block 0 is non-tail here (pos = 20 > block_size): stamped-verified
        eng.inject_kv_fault(layer=0, block=req.block_ids[0], head=0,
                            row=2, col=3, bit=27, into="k")
        eng.step()
        return eng.paged_stats.kv_detected_blocks

    assert poisoned() == 1                           # always: caught
    assert poisoned(kv_verify="stamped") == 0        # stamped: deferred


def test_paged_admission_is_head_of_line_fcfs(setup):
    """A queued request that cannot get its blocks must not be overtaken by
    a smaller later request (the scheduler-fairness contract, exercised
    through real block-pool pressure), and the freed prefix blocks of the
    finished request are evicted to make room."""
    cfg, model, params, rng = setup
    eng = _paged(model, params, n_slots=2, cache_len=32, block_size=8,
                 num_blocks=4)
    pa = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)  # 3 blocks
    pb = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)   # 2 blocks
    pc = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)   # 1 block
    ra = eng.submit(pa, max_new_tokens=4)
    eng.step()                                   # A admitted, holds 3 of 4
    rb = eng.submit(pb, max_new_tokens=3)        # needs 2: must wait
    rc = eng.submit(pc, max_new_tokens=3)        # needs 1: would fit NOW
    outs = eng.run()
    assert set(outs) == {ra, rb, rc}
    orders = {r.rid: r.admit_order for r in eng.scheduler.finished}
    assert orders[rb] < orders[rc], "small request jumped the FCFS queue"
