"""Property tests for the tensor-checksum algebra (paper §4.1).

Uses ``_propcheck``: real hypothesis when installed, a seeded deterministic
fallback otherwise (so the suite collects and runs either way)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import checksum as cks

pytestmark = pytest.mark.quick

jax.config.update("jax_enable_x64", False)


def arrays(rows, width, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, width)), jnp.float32)


@given(st.integers(1, 6), st.sampled_from([8, 16, 32]), st.integers(2, 6),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_fold_identity(rows, stride, g, seed):
    """fold1/fold2 are linear strided folds; reconstructable from segments."""
    x = arrays(rows, stride * g, seed)
    f1 = cks.fold1(x, stride)
    f2 = cks.fold2(x, stride)
    segs = x.reshape(rows, g, stride)
    np.testing.assert_allclose(f1, segs.sum(1), rtol=1e-5, atol=1e-5)
    w = np.arange(1, g + 1, dtype=np.float32)[:, None]
    np.testing.assert_allclose(f2, (np.asarray(segs) * w).sum(1),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16]), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_abft_gemm_identity(seed, stride, g):
    """Q @ encode(K).T == fold(Q @ K.T): the core ABFT invariant."""
    rng = np.random.default_rng(seed)
    d, bc = 16, stride * g
    q = jnp.asarray(rng.standard_normal((8, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bc, d)), jnp.float32)
    checks = cks.encode_kv(k, stride)
    s = q @ k.T
    np.testing.assert_allclose(q @ checks.c1.T, cks.fold1(s, stride),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(q @ checks.c2.T, cks.fold2(s, stride),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.integers(0, 7), st.integers(0, 3),
       st.floats(2.0, 100.0))
@settings(max_examples=40, deadline=None)
def test_single_error_located_and_corrected(seed, row, fold_col, magnitude):
    """Any single injected error above the (relative) threshold is exactly
    corrected. threshold=0.05 relative: detection bound is 0.05*max(|c1|,1),
    well below the injected magnitude >= 2 for N(0,1) folds of 4."""
    stride, g, rows = 4, 4, 8
    x = arrays(rows, stride * g, seed)
    checks = cks.Checksums(cks.fold1(x, stride), cks.fold2(x, stride))
    seg = seed % g
    col = seg * stride + fold_col % stride
    x_bad = x.at[row, col].add(magnitude)
    verdict = cks.verify_and_correct(x_bad, checks, stride, threshold=0.05)
    assert int(verdict.n_detected) == 1
    np.testing.assert_allclose(verdict.corrected, x, rtol=1e-4, atol=1e-4)


def test_no_false_positives_bf16():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.bfloat16)
    q = jnp.asarray(rng.standard_normal((32, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((128, 64)), jnp.bfloat16)
    checks = cks.encode_kv(k, 8)
    s = jnp.matmul(q, k.T, preferred_element_type=jnp.float32)
    c1 = jnp.matmul(q, checks.c1.T, preferred_element_type=jnp.float32)
    c2 = jnp.matmul(q, checks.c2.T, preferred_element_type=jnp.float32)
    verdict = cks.verify_and_correct(s, cks.Checksums(c1, c2), 8,
                                     threshold=0.5)
    assert int(verdict.n_detected) == 0


def test_traditional_abft_roundtrip():
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    rc = cks.traditional_encode_cols(c)  # exact row checksums of c itself
    bad = c.at[3, 17].add(7.5)
    verdict = cks.traditional_verify_correct(
        bad, rc, threshold=0.5)
    assert int(verdict.n_detected) == 1
    np.testing.assert_allclose(verdict.corrected, c, atol=1e-4)


def test_interleaved_multi_error_advantage():
    """Two errors in one row are corrected iff not aliased at the stride —
    the paper's up-to-8x (here 4x) coverage argument."""
    stride, g = 4, 4
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((4, stride * g)), jnp.float32)
    checks = cks.Checksums(cks.fold1(x, stride), cks.fold2(x, stride))
    # different fold columns -> both corrected
    bad = x.at[1, 2].add(5.0).at[1, 7].add(3.0)  # cols 2 and 3 of folds
    v = cks.verify_and_correct(bad, checks, stride, threshold=0.25)
    np.testing.assert_allclose(v.corrected, x, atol=1e-4)
    # same fold column (aliased at stride): NOT correctable (documented limit)
    bad2 = x.at[1, 2].add(5.0).at[1, 2 + stride].add(3.0)
    v2 = cks.verify_and_correct(bad2, checks, stride, threshold=0.25)
    assert not np.allclose(v2.corrected, x, atol=1e-3)


def test_verify_block_detects_resident_corruption():
    """Memory-integrity check of a stored KV block: recomputed folds vs
    resident checksums catch a single-element bit-flip-scale change in the
    block data or in the checksum itself."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((3, 16, 8)), jnp.float32)  # 3 blocks
    checks = cks.encode_kv(x, 4)
    bad, n = cks.verify_block(x, checks, 4, threshold=1e-3)
    assert int(n) == 0                        # clean data: no false positives
    flipped = x.at[1, 5, 2].multiply(-3.0)
    bad, n = cks.verify_block(flipped, checks, 4, threshold=1e-3)
    assert int(n) == 1
    assert np.asarray(bad).tolist() == [False, True, False]
    # a flip in the *checksum* is equally a detection (can't tell apart)
    bad2, n2 = cks.verify_block(
        x, cks.Checksums(checks.c1.at[0, 0, 0].add(50.0), checks.c2), 4,
        threshold=1e-3)
    assert int(n2) == 1 and bool(np.asarray(bad2)[0])
    # NaN corruption (exponent-bit upset) is detected, not compared-False
    bad3, n3 = cks.verify_block(x.at[2, 0, 0].set(jnp.nan), checks, 4,
                                threshold=1e-3)
    assert bool(np.asarray(bad3)[2])


def test_log_domain_product_check_covers_underflowed_columns():
    """ROADMAP EXP-coverage closure: a corruption of a *large* P entry in a
    fold column whose product underflows escapes the linear product check
    (prod ~ 0 == check ~ 0) but must be caught by the log-domain fold."""
    stride = 4
    p_true = np.array([[0.9, 0.8, 0.7, 0.6,
                        np.exp(-60.0), 0.5, 0.4, 0.3]], np.float32)
    log_check = cks.fold1(jnp.log(jnp.asarray(p_true)), stride)
    p_bad = p_true.copy()
    p_bad[0, 0] = 0.0                          # large entry wiped by an SEU
    # linear-domain check: both products are ~1e-40 -> blind
    p_check = cks.foldprod(jnp.asarray(p_true), stride)
    bad_lin, n_lin = cks.verify_product(jnp.asarray(p_bad), p_check, stride,
                                        threshold=1e-3)
    assert not bool(np.asarray(bad_lin)[0, 0])
    # log-domain check: sum of logs mismatches by ~100 nats -> detected
    bad_log, n_log = cks.verify_product_log(jnp.asarray(p_bad), log_check,
                                            stride, threshold=1e-3)
    assert bool(np.asarray(bad_log)[0, 0])
    # and no false positive on clean data
    ok, n_ok = cks.verify_product_log(jnp.asarray(p_true), log_check, stride,
                                      threshold=1e-3)
    assert int(n_ok) == 0
