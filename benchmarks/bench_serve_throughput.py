"""Serve-engine throughput: tokens/s vs batch size, FT on/off, against the
seed's per-token Python loop (``greedy_generate``, unjitted dispatch per
step) — the jitted fixed-shape batched decode must win at batch >= 4.

CPU-host caveat (benchmarks/common.py): absolute numbers are not TPU-scale;
the *ratios* (engine vs python loop, FT on vs off) are the metric.

  PYTHONPATH=src python -m benchmarks.bench_serve_throughput [--gen 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine, greedy_generate

PROMPT_LEN = 16
BATCHES = (1, 2, 4, 8)


def _python_loop_tokens_per_s(model, params, prompts, gen: int) -> float:
    t0 = time.perf_counter()
    out, _ = greedy_generate(model, params, prompts, steps=gen)
    jax.block_until_ready(out)
    return out.size / (time.perf_counter() - t0)


def _engine_tokens_per_s(model, params, prompts, gen: int) -> float:
    n = prompts.shape[0]
    # warm and time the SAME instance: each engine owns its own jax.jit of a
    # bound method, so a throwaway warm-up engine would not warm this one
    eng = ServeEngine(model, params, n_slots=n, cache_len=64)
    for row in np.asarray(prompts):
        eng.submit(row, max_new_tokens=2)
    eng.run()  # compiles prefill bucket + decode outside the timed region
    tokens_before = eng.stats.tokens
    for row in np.asarray(prompts):
        eng.submit(row, max_new_tokens=gen)
    t0 = time.perf_counter()
    eng.run()
    return (eng.stats.tokens - tokens_before) / (time.perf_counter() - t0)


def run(gen: int = 16) -> list[dict]:
    rows = []
    base = get_config("gpt2-smoke")
    rng = np.random.default_rng(0)
    print("# serve throughput: tokens/s, gpt2-smoke, "
          f"prompt={PROMPT_LEN} gen={gen}")
    print("batch,ft,python_loop_tok_s,engine_tok_s,speedup")
    for ft_mode in ("correct", "off"):
        cfg = dataclasses.replace(
            base, ft=dataclasses.replace(base.ft, mode=ft_mode))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        for b in BATCHES:
            prompts = jnp.asarray(rng.integers(
                0, cfg.vocab_size, (b, PROMPT_LEN)), jnp.int32)
            loop = _python_loop_tokens_per_s(model, params, prompts, gen)
            engine = _engine_tokens_per_s(model, params, prompts, gen)
            speedup = engine / loop
            rows.append({"batch": b, "ft": ft_mode, "loop": loop,
                         "engine": engine, "speedup": speedup})
            print(f"{b},{ft_mode},{loop:.1f},{engine:.1f},{speedup:.2f}x",
                  flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    rows = run(gen=args.gen)
    worst = min(r["speedup"] for r in rows if r["batch"] >= 4)
    print(f"# worst batch>=4 speedup: {worst:.2f}x "
          f"({'OK' if worst > 1 else 'REGRESSION'})")


if __name__ == "__main__":
    main()
