"""End-to-end serving driver (the paper's use case is inference): a small
LM serves batched requests while soft errors strike its attention layers.
EFTA corrects them in-kernel; the fault monitor escalates if they persist.

  PYTHONPATH=src python examples/serve_fault_tolerant.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.ft_runtime import FaultRateMonitor
from repro.models import build_model
from repro.serve import greedy_generate

cfg = get_config("gpt2-smoke")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
      f"ft={cfg.ft.mode} (EFTA stride {cfg.ft.stride})")
monitor = FaultRateMonitor()
for request in range(4):
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 24)), jnp.int32)
    out, rep = greedy_generate(model, params, prompts, steps=8)
    status = monitor.observe(int(np.sum(np.asarray(rep.detected))))
    print(f"request {request}: generated {out.shape[1]} tokens x "
          f"{out.shape[0]} seqs; EFTA detected={np.asarray(rep.detected)} "
          f"status={status}")

# same batch with FT disabled vs enabled must agree (no false corrections)
off = build_model(dataclasses.replace(
    cfg, ft=dataclasses.replace(cfg.ft, mode="off")))
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
a, _ = greedy_generate(model, params, prompts, steps=6)
b, _ = greedy_generate(off, params, prompts, steps=6)
assert (np.asarray(a) == np.asarray(b)).all()
print("OK: EFTA-protected decoding is bit-identical to unprotected decoding "
      "in the fault-free case.")
