"""Serving launcher: batched fault-tolerant inference (prefill + decode).

CPU-scale demo:
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-smoke \
      --batch 4 --prompt-len 32 --gen 16 --inject-faults 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import greedy_generate
from repro.utils import get_logger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    log = get_logger("serve")

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    kw = {}
    if cfg.family in ("vlm", "audio"):
        kw["frontend"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    t0 = time.time()
    out, rep = greedy_generate(model, params, tokens, steps=args.gen, **kw)
    dt = time.time() - t0
    log.info("generated %s tokens in %.2fs (%.1f tok/s)", out.shape, dt,
             out.size / dt)
    log.info("EFTA report: detected=%s corrected=%s",
             np.asarray(rep.detected).tolist(),
             np.asarray(rep.corrected).tolist())
    print(np.asarray(out))


if __name__ == "__main__":
    main()
