"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, d_ff=24576, vocab_size=49152,
    attn=AttnCfg(num_heads=48, num_kv_heads=4, head_dim=128),
    glu=False, act="gelu",
    source="arXiv:2402.19173",
)
