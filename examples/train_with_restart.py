"""Fault-tolerant training driver: train, snapshot asynchronously, simulate a
node crash, resume from the latest checkpoint, verify the trajectory is
identical (stateless data pipeline + deterministic resume).

  PYTHONPATH=src python examples/train_with_restart.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_pipeline
from repro.ft_runtime import AsyncCheckpointer, StragglerMonitor, latest_step, restore
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import init_state, make_train_step

cfg = get_config("gpt2-smoke")
model = build_model(cfg)
opt = AdamW(lr=warmup_cosine(5e-3, warmup=5, total=40))
data = make_pipeline(cfg, global_batch=8, seq_len=32, seed=0)
step_fn = jax.jit(make_train_step(model, opt))
ckpt = AsyncCheckpointer()
mon = StragglerMonitor()
root = Path(tempfile.mkdtemp(prefix="efta_ckpt_"))

state = init_state(model, opt, jax.random.PRNGKey(0))
print("run A: training 20 steps, async checkpoint at step 10")
for i in range(20):
    mon.step_start()
    batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    state, metrics = step_fn(state, batch)
    v = mon.step_end()
    if i + 1 == 10:
        ckpt.save_async(root / f"step_{i+1}", state, step=i + 1)
        print(f"  step {i+1}: loss {float(metrics['loss']):.4f} "
              f"(snapshot in flight, {v.step_time:.3f}s/step)")
ckpt.wait()
loss_a = float(metrics["loss"])

print("simulated crash. run B: resume from latest checkpoint")
template = init_state(model, opt, jax.random.PRNGKey(0))
state_b, step0, _ = restore(latest_step(root), template)
print(f"  resumed at step {step0}")
for i in range(step0, 20):
    batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    state_b, metrics_b = step_fn(state_b, batch)
loss_b = float(metrics_b["loss"])
print(f"run A final loss {loss_a:.6f} | run B final loss {loss_b:.6f}")
np.testing.assert_allclose(loss_a, loss_b, rtol=1e-5)
print("OK: crash-resume reproduced the exact training trajectory.")
