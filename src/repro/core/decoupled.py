"""Decoupled fault-tolerant attention — the paper's baseline (§3.1, Figs 2-3).

Three *separate* kernels, each a distinct jitted executable so the CPU analog
of "kernel launch + HBM round trip" is honest:

  kernel I   : ABFT-GEMM  S = Q·Kᵀ   (classic rank-1 checksums, S materialized)
  kernel II  : DMR row-softmax        (redundant re-execution + comparison)
  kernel III : ABFT-GEMM  O = P·V    (classic rank-1 checksums, P materialized)

The O(n²) S and P tensors round-trip through host/HBM between kernels — this
is exactly the memory blowup the paper's Fig. 9 shows OOMing at 16k tokens.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import checksum as cks
from repro.core.efta import MASK_VALUE, FTReport, _full_mask
from repro.core.fault import FaultSpec, Site, inject


@functools.partial(jax.jit, static_argnames=("correct",))
def abft_gemm_qk(q, k, *, correct: bool = True, fault=None):
    """Kernel I: S = Q Kᵀ with traditional rank-1 ABFT (paper eq. 9-10).

    ``fault`` (Site.GEMM1) is injected between compute and verification —
    inside the kernel, as in the paper's model."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    # Row checksums of S predicted from K's column checksums: S @ [1, w].
    k_t = jnp.swapaxes(k, -1, -2)                     # (B,H,D,Skv)
    kc = cks.traditional_encode_cols(k_t)             # (B,H,D,2)
    s = jnp.einsum("bhqd,bhdc->bhqc", q, k_t,
                   preferred_element_type=jnp.float32) * scale
    s = inject(s, fault, Site.GEMM1, 0)
    s_checks = jnp.einsum("bhqd,bhdc->bhqc", q, kc,
                          preferred_element_type=jnp.float32) * scale
    verdict = cks.traditional_verify_correct(
        s, s_checks, threshold=5e-2 if q.dtype != jnp.float32 else 1e-3,
        correct=correct)
    return verdict.corrected, verdict.n_detected


@functools.partial(jax.jit, static_argnames=("causal",))
def dmr_row_softmax(s, *, causal: bool = False):
    """Kernel II: row softmax with dual modular redundancy (paper eq. 11-12).

    The softmax is executed twice; results must agree within tolerance and
    each row of P must sum to ~1 (the c1 invariant). Disagreement triggers a
    third (tie-break) execution — here the recomputation is the correction.
    """
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        m = _full_mask(sq, skv, causal=True, window=None, kv_len=None, q_offset=skv - sq)
        s = jnp.where(m, s, MASK_VALUE)
    p1 = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    # the optimization barrier defeats CSE so the redundant execution is
    # real (software DMR under an optimizing compiler is otherwise vacuous)
    p2 = jax.nn.softmax(jax.lax.optimization_barrier(s.astype(jnp.float32)),
                        axis=-1)
    agree = jnp.abs(p1 - p2) < 1e-6
    rowsum_ok = jnp.abs(p1.sum(-1) - 1.0) < 1e-3
    n_detected = (~agree).sum(dtype=jnp.int32) + (~rowsum_ok).sum(dtype=jnp.int32)
    p = jnp.where(agree, (p1 + p2) * 0.5, p1)
    return p.astype(s.dtype), n_detected


@functools.partial(jax.jit, static_argnames=("correct",))
def abft_gemm_pv(p, v, *, correct: bool = True, fault=None):
    """Kernel III: O = P V with traditional rank-1 ABFT (row-tiled variant)."""
    vc = cks.traditional_encode_cols(v)               # (B,H,Skv,2)
    o = jnp.einsum("bhqc,bhcd->bhqd", p, v,
                   preferred_element_type=jnp.float32)
    o = inject(o, fault, Site.GEMM2, 0)
    o_checks = jnp.einsum("bhqc,bhcd->bhqd", p, vc,
                          preferred_element_type=jnp.float32)
    verdict = cks.traditional_verify_correct(
        o, o_checks, threshold=5e-2 if p.dtype != jnp.float32 else 1e-3,
        correct=correct)
    return verdict.corrected.astype(p.dtype), verdict.n_detected


def decoupled_ft_attention(q, k, v, *, causal: bool = False,
                           fault: Optional[FaultSpec] = None,
                           correct: bool = True):
    """Full decoupled pipeline: 3 kernels, S and P materialized in HBM.

    GQA is handled by repeating KV heads (the decoupled baseline predates GQA
    kernels — repetition is what a naive integration does, and it charges the
    honest memory bill).
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    # Faults at GEMM sites are injected *inside* the owning kernel (caught by
    # that kernel's ABFT). A Site.EXP fault is injected into P *between*
    # kernels II and III — the decoupled framework's inter-kernel memory gap
    # (the fused EFTA has no such boundary; see Fig. 9 benches).
    s, n1 = abft_gemm_qk(q, k, correct=correct, fault=fault)
    jax.block_until_ready(s)  # kernel boundary: S round-trips through HBM
    p, n2 = dmr_row_softmax(s, causal=causal)
    p = inject(p, fault, Site.EXP, 0)
    jax.block_until_ready(p)  # kernel boundary: P round-trips through HBM
    p = p.astype(q.dtype)
    o, n3 = abft_gemm_pv(p, v, correct=correct, fault=fault)
    detected = jnp.stack([n1, n2, jnp.int32(0), jnp.int32(0), n3])
    rep = FTReport(detected, detected if correct else detected * 0,
                   jnp.zeros((3,), jnp.float32))
    return o.astype(q.dtype), rep


def decoupled_memory_bytes(b, h, sq, skv, dtype=jnp.bfloat16) -> int:
    """Analytic HBM footprint of the intermediates (S and P) the decoupled
    framework materializes — the quantity that OOMs at 16k in paper Fig. 9."""
    itemsize = jnp.dtype(dtype).itemsize
    return 2 * b * h * sq * skv * itemsize
