from repro.serve.cache import KVCachePool
from repro.serve.blocks import BlockPool, PrefixCache
from repro.serve.draft import (DraftModelProposer, NGramProposer,
                               build_proposer)
from repro.serve.engine import EngineStats, ServeEngine, batch_faults
from repro.serve.paged import (PagedCacheStats, PagedKVPool, PagedServeEngine)
from repro.serve.sampling import (SamplingParams, sample_tokens,
                                  speculative_accept)
from repro.serve.scheduler import (ContinuousBatchingScheduler, Request,
                                   RequestState)
from repro.serve.step import greedy_generate, make_decode_step, make_prefill_step
