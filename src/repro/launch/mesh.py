"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Production shapes: 16x16 = 256 chips per pod; 2x16x16 = two pods.
Scaling past two pods appends to the leading ``pod`` axis (pure DP/FSDP
across pods — only gradient syncs cross the inter-pod fabric).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this automatically)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
