"""Randomized SEU injection campaigns over EFTA attention (paper §5.3).

Shared between ``examples/fault_injection_campaign.py`` and the deterministic
tier-1 campaign test: inject N random single-bit faults across the paper's
attention sites and classify every trial against the fault-free oracle as

  * ``harmless``  — output unchanged within tolerance (low bit / masked slot,
                    or the site cancels analytically, e.g. ROWMAX Case 1)
  * ``corrected`` — detected and repaired (output back within tolerance)
  * ``detected``  — detected but visibly corrupted (detect-only modes)
  * ``silent``    — corrupted with no detection (the failure mode EFTA
                    exists to eliminate)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.efta import EFTAConfig, efta_attention, reference_attention
from repro.core.fault import FaultSpec, Site, random_fault

DEFAULT_SITES = (Site.GEMM1, Site.EXP, Site.ROWMAX, Site.ROWSUM, Site.GEMM2)


@dataclasses.dataclass
class SiteTally:
    trials: int = 0
    harmless: int = 0
    corrected: int = 0
    detected: int = 0
    silent: int = 0
    worst_residual: float = 0.0


@dataclasses.dataclass
class CampaignResult:
    mode: str
    n_trials: int
    per_site: Dict[Site, SiteTally]
    worst_residual: float = 0.0

    @property
    def totals(self) -> SiteTally:
        t = SiteTally()
        for s in self.per_site.values():
            t.trials += s.trials
            t.harmless += s.harmless
            t.corrected += s.corrected
            t.detected += s.detected
            t.silent += s.silent
            t.worst_residual = max(t.worst_residual, s.worst_residual)
        return t

    def format_table(self) -> str:
        rows = [f"mode={self.mode}  trials={self.n_trials}  "
                f"worst_residual={self.worst_residual:.2e}"]
        hdr = f"  {'site':8s} {'trials':>6s} {'harmless':>8s} " \
              f"{'corrected':>9s} {'detected':>8s} {'SILENT':>7s}"
        rows.append(hdr)
        for site, t in sorted(self.per_site.items(), key=lambda kv: kv[0]):
            rows.append(f"  {site.name:8s} {t.trials:6d} {t.harmless:8d} "
                        f"{t.corrected:9d} {t.detected:8d} {t.silent:7d}")
        return "\n".join(rows)


def run_campaign(
    *,
    mode: str = "correct",
    n_trials: int = 50,
    seed: int = 0,
    shape_bhsd: Tuple[int, int, int, int] = (1, 4, 128, 32),
    block_kv: int = 32,
    stride: int = 8,
    sites: Sequence[Site] = DEFAULT_SITES,
    bit_range: Tuple[int, int] = (16, 30),
    tol: float = 1e-3,
    cfg: Optional[EFTAConfig] = None,
) -> CampaignResult:
    """Run a seeded SEU campaign against a fixed random attention problem."""
    b, h, s, d = shape_bhsd
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    ref = np.asarray(reference_attention(q, k, v), np.float32)
    cfg = cfg or EFTAConfig(mode=mode, stride=stride, block_kv=block_kv)
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    rng = np.random.default_rng(seed + 1)

    result = CampaignResult(mode=mode, n_trials=n_trials,
                            per_site={site: SiteTally() for site in sites})
    n_blocks = max(s // block_kv, 1)
    for _ in range(n_trials):
        spec = random_fault(rng, sites=sites, shape_bhsc=(b, h, s, s),
                            n_blocks=n_blocks, max_bit=bit_range[1])
        # random_fault samples bits uniformly in [0, max_bit]; re-draw the
        # bit into the campaign's range (high bits = visible corruptions).
        bit = int(rng.integers(bit_range[0], bit_range[1] + 1))
        spec = spec._replace(bit=jnp.asarray([bit], jnp.int32))
        site = Site(int(spec.site[0]))
        out, rep = fn(q, k, v, fault=spec)
        err = float(np.max(np.abs(np.asarray(out, np.float32) - ref)))
        det = int(np.sum(np.asarray(rep.detected))) > 0
        t = result.per_site[site]
        t.trials += 1
        t.worst_residual = max(t.worst_residual, err)
        result.worst_residual = max(result.worst_residual, err)
        if err < tol:
            if det:
                t.corrected += 1
            else:
                t.harmless += 1
        elif det:
            t.detected += 1
        else:
            t.silent += 1
    return result
