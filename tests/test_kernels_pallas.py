"""Pallas EFTA kernel vs pure-jnp oracle (interpret mode), shape/dtype sweep
plus in-kernel fault injection at every site."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EFTAConfig
from repro.kernels import efta_attention_pallas
from repro.kernels.ref import attention_ref


def qkv(b, h, hkv, s, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, s, d), dtype),
            jax.random.normal(ks[1], (b, hkv, s, d), dtype),
            jax.random.normal(ks[2], (b, hkv, s, d), dtype))


SWEEP = [
    # (b, h, hkv, s, d, block_q, block_kv, stride)
    (1, 2, 2, 128, 32, 64, 64, 8),
    (2, 4, 2, 256, 64, 128, 128, 8),
    (1, 4, 1, 256, 128, 128, 256, 128),
    (1, 2, 2, 512, 64, 128, 128, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,d,bq,bkv,stride", SWEEP)
def test_kernel_vs_oracle(b, h, hkv, s, d, bq, bkv, stride, dtype):
    q, k, v = qkv(b, h, hkv, s, d, dtype)
    cfg = EFTAConfig(mode="correct", stride=stride, block_kv=bkv)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, block_q=bq)
    ref = attention_ref(q, k, v)
    tol = 2e-6 if dtype == jnp.float32 else 2.5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
    assert int(det.sum()) == 0


@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_kernel_masks(causal, window):
    q, k, v = qkv(1, 2, 2, 256, 32, jnp.float32)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=64)
    out, _ = efta_attention_pallas(q, k, v, cfg=cfg, causal=causal,
                                   window=window, block_q=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-6)


@pytest.mark.parametrize("site", [0, 1, 2, 3, 4])
def test_kernel_fault_injection(site):
    q, k, v = qkv(1, 4, 2, 256, 64, jnp.float32)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=128)
    ref = attention_ref(q, k, v)
    fault = jnp.array([site, 1, 2, 130, 21, 27, 1, 0], jnp.int32)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, fault=fault,
                                     block_q=128)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 5e-3, f"site {site}: err {err}"


@pytest.mark.parametrize("h,hkv", [(8, 2), (4, 1), (6, 3)])
def test_kernel_gqa_grouping(h, hkv):
    """GQA/MQA head grouping parity vs the oracle (previously only covered
    for the pure-JAX path in test_efta.py)."""
    q, k, v = qkv(2, h, hkv, 128, 32, jnp.float32, seed=3)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=64)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, block_q=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=3e-6)
    assert int(det.sum()) == 0


@pytest.mark.parametrize("kv_len", [96, 200, 256])
@pytest.mark.parametrize("causal", [False, True])
def test_kernel_ragged_kv_len(kv_len, causal):
    """Serving-style ragged KV: the cache holds 256 block-aligned slots but
    only ``kv_len`` are valid. Must match the oracle's kv_len mask and keep
    a clean detection report (the masked tail is no false-positive source)."""
    from repro.core.efta import reference_attention
    q, k, v = qkv(1, 4, 2, 256, 64, jnp.float32, seed=4)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=64)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, causal=causal,
                                     kv_len=kv_len, block_q=128)
    ref = reference_attention(q, k, v, causal=causal, kv_len=kv_len)
    np.testing.assert_allclose(out, ref, atol=3e-6)
    assert int(det.sum()) == 0


def test_kernel_gqa_ragged_combined_matches_jnp_efta():
    """GQA + ragged kv_len together, cross-checked against the pure-JAX EFTA
    twin (both fault-tolerance paths active)."""
    from repro.core.efta import efta_attention
    q, k, v = qkv(1, 8, 2, 256, 32, jnp.float32, seed=5)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=64)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, kv_len=130,
                                     block_q=128)
    ref, rep = efta_attention(q, k, v, cfg=cfg, kv_len=130)
    np.testing.assert_allclose(out, ref, atol=3e-6)
    assert int(det.sum()) == 0 and int(rep.detected.sum()) == 0


def test_kernel_ragged_fault_still_corrected():
    """A GEMM1 SEU inside the valid ragged prefix is corrected as usual."""
    q, k, v = qkv(1, 4, 2, 256, 64, jnp.float32, seed=6)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=64)
    from repro.core.efta import reference_attention
    ref = reference_attention(q, k, v, kv_len=150)
    fault = jnp.array([0, 1, 2, 17, 21, 27, 1, 0], jnp.int32)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, kv_len=150,
                                     fault=fault, block_q=128)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 5e-3, err
    assert int(det.sum()) >= 1


def test_kernel_off_mode_is_plain_flash():
    q, k, v = qkv(1, 2, 2, 256, 32, jnp.float32)
    cfg = EFTAConfig(mode="off", stride=8, block_kv=64)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, block_q=64)
    np.testing.assert_allclose(out, attention_ref(q, k, v), atol=2e-6)
    assert int(det.sum()) == 0


def test_kernel_unified_vs_stepwise():
    q, k, v = qkv(1, 2, 2, 256, 32, jnp.float32)
    for unified in (True, False):
        cfg = EFTAConfig(mode="correct", stride=8, block_kv=64,
                         unified=unified)
        out, _ = efta_attention_pallas(q, k, v, cfg=cfg, block_q=64)
        np.testing.assert_allclose(out, attention_ref(q, k, v), atol=2e-6)
