"""Runtime distribution context (mesh + axis roles), threaded implicitly.

Avoids plumbing mesh handles through every layer signature: the train/serve
step factories set the context; attention/MoE read it.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Optional[object] = None
    cp_axis: Optional[str] = None     # context-parallel axis for long decode
    ep_axis: str = "model"


_CURRENT = DistContext()


def current() -> DistContext:
    return _CURRENT


@contextlib.contextmanager
def use_context(**kw):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = dataclasses.replace(prev, **kw)
    try:
        yield _CURRENT
    finally:
        _CURRENT = prev
