"""Randomized SEU injection campaigns over EFTA attention (paper §5.3).

Shared between ``examples/fault_injection_campaign.py`` and the deterministic
tier-1 campaign test: inject N random single-bit faults across the paper's
attention sites and classify every trial against the fault-free oracle as

  * ``harmless``  — output unchanged within tolerance (low bit / masked slot,
                    or the site cancels analytically, e.g. ROWMAX Case 1)
  * ``corrected`` — detected and repaired (output back within tolerance)
  * ``detected``  — detected but visibly corrupted (detect-only modes)
  * ``silent``    — corrupted with no detection (the failure mode EFTA
                    exists to eliminate)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.efta import EFTAConfig, efta_attention, reference_attention
from repro.core.fault import FaultSpec, Site, random_fault

DEFAULT_SITES = (Site.GEMM1, Site.EXP, Site.ROWMAX, Site.ROWSUM, Site.GEMM2)


@dataclasses.dataclass
class SiteTally:
    trials: int = 0
    harmless: int = 0
    corrected: int = 0
    detected: int = 0
    silent: int = 0
    worst_residual: float = 0.0


@dataclasses.dataclass
class CampaignResult:
    mode: str
    n_trials: int
    per_site: Dict[Site, SiteTally]
    worst_residual: float = 0.0

    @property
    def totals(self) -> SiteTally:
        t = SiteTally()
        for s in self.per_site.values():
            t.trials += s.trials
            t.harmless += s.harmless
            t.corrected += s.corrected
            t.detected += s.detected
            t.silent += s.silent
            t.worst_residual = max(t.worst_residual, s.worst_residual)
        return t

    def format_table(self) -> str:
        rows = [f"mode={self.mode}  trials={self.n_trials}  "
                f"worst_residual={self.worst_residual:.2e}"]
        hdr = f"  {'site':8s} {'trials':>6s} {'harmless':>8s} " \
              f"{'corrected':>9s} {'detected':>8s} {'SILENT':>7s}"
        rows.append(hdr)
        for site, t in sorted(self.per_site.items(), key=lambda kv: kv[0]):
            rows.append(f"  {site.name:8s} {t.trials:6d} {t.harmless:8d} "
                        f"{t.corrected:9d} {t.detected:8d} {t.silent:7d}")
        return "\n".join(rows)


@dataclasses.dataclass
class KVCampaignResult:
    """Outcome of a resident-KV SEU campaign (Site.KV) over the paged serve
    engine: every trial flips one bit of a live KV-cache block *between*
    decode steps, mimicking an HBM upset in stored state that EFTA's
    in-compute checks cannot see."""

    n_trials: int = 0
    detected: int = 0            # caught by block checksums at gather time
    repaired_blocks: int = 0     # blocks re-prefilled by the engine
    undetected: int = 0          # below-threshold flips (denormal/low-impact)
    mismatched_requests: int = 0  # final tokens differing from the clean run
    telemetry_kv_detected: int = 0  # per-request site-6 counts (ServeFault...)

    def format_table(self) -> str:
        return (f"KV campaign: trials={self.n_trials} "
                f"detected={self.detected} repaired={self.repaired_blocks} "
                f"undetected={self.undetected} "
                f"mismatched_requests={self.mismatched_requests}")


def run_kv_campaign(
    *,
    n_trials: int = 12,
    seed: int = 0,
    arch: str = "gpt2-smoke",
    n_slots: int = 2,
    cache_len: int = 64,
    block_size: int = 16,
    n_requests: int = 3,
    max_prompt: int = 24,
    gen: int = 8,
    bit_range: Tuple[int, int] = (24, 30),
    kernel: str = "gather",
    chunk_size: Optional[int] = None,
    chunk_budget: Optional[int] = None,
) -> KVCampaignResult:
    """Seeded SEU campaign against *resident* KV state (paper's gap: ALBERTA-
    style memory faults, not compute faults).

    Drives one clean and one faulted :class:`repro.serve.PagedServeEngine`
    over the same request stream; each trial flips a random high bit of a
    random filled row of a random live block. The engine must detect the
    corruption at the next read, re-prefill only the poisoned block, retry
    the step, and finish with tokens identical to the clean run.

    ``kernel`` selects the decode backend under test: ``"gather"`` verifies
    at gather time outside the kernel; ``"fused"`` drives the SEUs through
    the fused paged-attention kernel's in-loop verify (and the append-time
    tail check), exercising the same detect→repair→token-identical contract.
    ``chunk_size``/``chunk_budget`` configure the unified chunked step —
    a ``chunk_size`` below ``max_prompt`` forces prompts to prefill across
    several mixed batches, so resident SEUs strike mid-prefill state and the
    detect→repair path is exercised through the chunked kernel too.
    """
    # local imports: core.campaign is imported by repro.core's __init__, and
    # repro.serve imports repro.core — module-level imports would cycle
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.paged import PagedServeEngine

    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed + 1)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (int(rng.integers(2, max_prompt + 1)),)
                            ).astype(np.int32) for _ in range(n_requests)]

    def fresh():
        eng = PagedServeEngine(model, params, n_slots=n_slots,
                               cache_len=cache_len, block_size=block_size,
                               kernel=kernel, chunk_size=chunk_size,
                               chunk_budget=chunk_budget)
        for p in prompts:
            eng.submit(p, max_new_tokens=gen)
        return eng

    clean_eng = fresh()
    clean = clean_eng.run()

    eng = fresh()
    res = KVCampaignResult()
    hkv = cfg.attn.num_kv_heads
    while eng.scheduler.has_work:
        active = [r for r in eng.scheduler.active_rows() if not r.is_done()]
        candidates = [r for r in active if eng._pos[r.slot] > 0]
        if candidates and res.n_trials < n_trials:
            req = candidates[int(rng.integers(0, len(candidates)))]
            resident = int(eng._pos[req.slot])
            j = int(rng.integers(0, -(-resident // block_size)))
            filled = min(block_size, resident - j * block_size)
            before = eng.paged_stats.kv_detected_blocks
            eng.inject_kv_fault(
                layer=int(rng.integers(0, cfg.num_layers)),
                block=req.block_ids[j],
                head=int(rng.integers(0, hkv)),
                row=int(rng.integers(0, filled)),
                col=int(rng.integers(0, cfg.attn.head_dim)),
                bit=int(rng.integers(bit_range[0], bit_range[1] + 1)),
                into="k" if rng.integers(0, 2) else "v")
            res.n_trials += 1
            eng.step()
            if eng.paged_stats.kv_detected_blocks > before:
                res.detected += 1
            else:
                res.undetected += 1
        else:
            eng.step()
    faulty = {r.rid: np.asarray(r.generated, np.int32)
              for r in eng.scheduler.finished}
    res.repaired_blocks = eng.paged_stats.kv_repaired_blocks
    res.mismatched_requests = sum(
        0 if np.array_equal(clean[rid], faulty[rid]) else 1 for rid in clean)
    res.telemetry_kv_detected = sum(
        st.detected[5] for st in eng.telemetry.requests.values())
    return res


def run_campaign(
    *,
    mode: str = "correct",
    n_trials: int = 50,
    seed: int = 0,
    shape_bhsd: Tuple[int, int, int, int] = (1, 4, 128, 32),
    block_kv: int = 32,
    stride: int = 8,
    sites: Sequence[Site] = DEFAULT_SITES,
    bit_range: Tuple[int, int] = (16, 30),
    tol: float = 1e-3,
    cfg: Optional[EFTAConfig] = None,
) -> CampaignResult:
    """Run a seeded SEU campaign against a fixed random attention problem."""
    b, h, s, d = shape_bhsd
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    ref = np.asarray(reference_attention(q, k, v), np.float32)
    cfg = cfg or EFTAConfig(mode=mode, stride=stride, block_kv=block_kv)
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    rng = np.random.default_rng(seed + 1)

    result = CampaignResult(mode=mode, n_trials=n_trials,
                            per_site={site: SiteTally() for site in sites})
    n_blocks = max(s // block_kv, 1)
    for _ in range(n_trials):
        spec = random_fault(rng, sites=sites, shape_bhsc=(b, h, s, s),
                            n_blocks=n_blocks, max_bit=bit_range[1])
        # random_fault samples bits uniformly in [0, max_bit]; re-draw the
        # bit into the campaign's range (high bits = visible corruptions).
        bit = int(rng.integers(bit_range[0], bit_range[1] + 1))
        spec = spec._replace(bit=jnp.asarray([bit], jnp.int32))
        site = Site(int(spec.site[0]))
        out, rep = fn(q, k, v, fault=spec)
        err = float(np.max(np.abs(np.asarray(out, np.float32) - ref)))
        det = int(np.sum(np.asarray(rep.detected))) > 0
        t = result.per_site[site]
        t.trials += 1
        t.worst_residual = max(t.worst_residual, err)
        result.worst_residual = max(result.worst_residual, err)
        if err < tol:
            if det:
                t.corrected += 1
            else:
                t.harmless += 1
        elif det:
            t.detected += 1
        else:
            t.silent += 1
    return result
