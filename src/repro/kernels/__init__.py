from repro.kernels.efta_attention import efta_attention_pallas
from repro.kernels.efta_paged import (PagedReport, efta_paged_attention_pallas,
                                      paged_fault_descriptor)
from repro.kernels.ops import attention, attention_jit, gather_block_kv
