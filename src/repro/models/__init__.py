from repro.models.api import Model, build_model
from repro.models.transformer import forward, init_params, layer_flags
