"""Host-side bookkeeping for the paged KV-cache: block pool + prefix cache.

This module is deliberately device-free (plain Python, no jax): it decides
*which* pool blocks hold *whose* tokens; ``repro.serve.paged`` owns the
device arrays and moves data. Splitting the two keeps the allocator unit-
testable and the jitted programs shape-stable.

Design (vLLM-style):

  * Block 0 is the reserved **null block**: padded block-table entries point
    at it, padded scatters write into it, and it is never allocated. That
    keeps every gather/scatter a fixed-shape fancy-index with no masks on the
    device side.
  * Every allocated block carries a **refcount** (number of requests mapping
    it). Full blocks whose content is immutable can additionally be
    **registered** under a token-hash chain; a registered block whose
    refcount drops to zero is not freed but parked in an LRU of evictable
    blocks — a later request with the same prefix re-hits it for free, and
    pool pressure reclaims it oldest-first (``alloc`` evicts transparently).
  * **Copy-on-write**: appending to a block another request can still see
    (ref > 1, or parked in the prefix cache) must first split it. ``cow``
    hands back a private block id and tells the caller to copy the device
    data.
  * The **prefix cache** keys full blocks by a hash *chain*
    (``h_j = H(h_{j-1}, tokens_j)``) so a hit certifies the entire prefix,
    and every lookup re-checks token identity — a hash collision degrades to
    a miss, never to cross-request token leakage.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

NULL_BLOCK = 0


@dataclasses.dataclass
class BlockMeta:
    """Lifetime bookkeeping for one pool block."""

    bid: int
    ref: int = 0
    # set once the block is full and registered in the prefix cache
    chain_hash: Optional[int] = None
    # generation stamps for read-time verification amortization: ``gen``
    # moves on every engine write to the block's device data (scatter,
    # append, COW copy, repair); ``verified_gen`` records the generation the
    # block's checksums last verified clean at read time. A block whose
    # stamps match was proven intact and untouched since — the stamped
    # policy skips re-folding it.
    gen: int = 0
    verified_gen: int = -1
    # monotone pool-wide clock value of the last read-time verification —
    # the background scrub pass re-folds oldest-verified-first so the
    # stamped policy's deferred-detection window stays bounded
    verified_at: int = -1


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    evictions: int = 0
    cow_copies: int = 0


class BlockPool:
    """Refcounted fixed-size block allocator with LRU reuse of cached blocks.

    ``on_evict(bid, chain_hash)`` is called when pool pressure reclaims a
    parked prefix-cache block, so the :class:`PrefixCache` can forget its
    mapping. The pool never touches device memory.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("need at least one allocatable block")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # ids 1..num_blocks; 0 is the null block
        self._free: collections.deque = collections.deque(
            range(1, num_blocks + 1))
        self._meta: Dict[int, BlockMeta] = {}
        # parked prefix-cache blocks (ref == 0, registered), LRU order
        self._evictable: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self.on_evict = lambda bid, chain_hash: None
        self.stats = PoolStats()
        self._verify_clock = 0

    # -- capacity -----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Blocks allocatable right now (free list + evictable cache)."""
        return len(self._free) + len(self._evictable)

    @property
    def live_blocks(self) -> int:
        return len(self._meta)

    def ref_of(self, bid: int) -> int:
        return self._meta[bid].ref if bid in self._meta else 0

    def is_shared(self, bid: int) -> bool:
        """True when another holder (a request or the prefix cache) can still
        observe this block — appending to it requires copy-on-write."""
        m = self._meta.get(bid)
        return m is not None and (m.ref > 1 or m.chain_hash is not None)

    # -- alloc / free -------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Allocate a block (ref = 1), evicting the LRU parked prefix-cache
        block under pressure. None when truly out of blocks."""
        if self._free:
            bid = self._free.popleft()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)
            meta = self._meta.pop(bid)
            self.stats.evictions += 1
            self.on_evict(bid, meta.chain_hash)
        else:
            return None
        self._meta[bid] = BlockMeta(bid=bid, ref=1)
        self.stats.allocs += 1
        return bid

    def ref_inc(self, bid: int) -> None:
        meta = self._meta[bid]
        if meta.ref == 0:       # re-hit of a parked cached block
            self._evictable.pop(bid, None)
        meta.ref += 1

    def ref_dec(self, bid: int) -> None:
        meta = self._meta.get(bid)
        if meta is None or meta.ref <= 0:
            raise ValueError(f"block {bid} double-freed")
        meta.ref -= 1
        if meta.ref > 0:
            return
        if meta.chain_hash is not None:
            # keep content for future prefix hits; reclaimable LRU-first
            self._evictable[bid] = None
        else:
            del self._meta[bid]
            self._free.append(bid)

    # -- generation stamps (read-time verification amortization) ------------
    def note_write(self, bid: int) -> None:
        """Record that the engine rewrote this block's device data (and
        refreshed its checksums): any read-time verification stamp is now
        stale. Unknown/null ids are ignored."""
        m = self._meta.get(bid)
        if m is not None:
            m.gen += 1

    def mark_verified(self, bid: int) -> None:
        """Stamp the block as read-time verified at its current generation
        (call only after a decode attempt that folded it committed clean)."""
        m = self._meta.get(bid)
        if m is not None:
            m.verified_gen = m.gen
            m.verified_at = self._verify_clock
            self._verify_clock += 1

    def needs_verify(self, bid: int) -> bool:
        """True unless the block verified clean at its current generation.
        Freshly (re)allocated blocks always need a first verification."""
        m = self._meta.get(bid)
        return m is None or m.verified_gen != m.gen

    def verified_at(self, bid: int) -> int:
        """Verification recency (monotone clock; -1 = never verified).
        The scrub pass re-folds the lowest values first."""
        m = self._meta.get(bid)
        return -1 if m is None else m.verified_at

    # -- parked prefix-cache blocks (background scrub coverage) --------------
    def parked_blocks(self) -> List[int]:
        """Blocks parked in the prefix cache (ref == 0, content retained for
        future hits). They appear in no live block table, so the read-time
        verification never touches them — the background scrub draws from
        this list after the live tables so a bit flip that lands while a
        shared prefix is parked is caught *before* the next admission
        gathers it."""
        return list(self._evictable)

    def discard_parked(self, bid: int) -> None:
        """Drop a parked block whose content failed verification: forget its
        prefix-cache registration (``on_evict``) and return it to the free
        list. Detection-before-use repair for cache-only state — the next
        admission simply misses and re-prefills fresh blocks."""
        if bid not in self._evictable:
            raise ValueError(f"block {bid} is not parked")
        del self._evictable[bid]
        meta = self._meta.pop(bid)
        self._free.append(bid)
        self.on_evict(bid, meta.chain_hash)

    # -- sharing ------------------------------------------------------------
    def register(self, bid: int, chain_hash: int) -> None:
        """Mark a (full, immutable) block as prefix-cache content."""
        self._meta[bid].chain_hash = chain_hash

    def touch(self, bid: int) -> None:
        """Refresh LRU recency of a parked block (on prefix-cache hit)."""
        if bid in self._evictable:
            self._evictable.move_to_end(bid)

    def cow(self, bid: int) -> Tuple[Optional[int], bool]:
        """Prepare ``bid`` for an append. Returns ``(write_bid, needs_copy)``:
        the id to write through, and whether the caller must copy the device
        block (old -> new) first. Drops this holder's ref on the shared
        original. None when the pool cannot supply the private copy."""
        if not self.is_shared(bid):
            return bid, False
        new = self.alloc()
        if new is None:
            return None, False
        self.ref_dec(bid)
        self.stats.cow_copies += 1
        return new, True


def chain_hash(parent: Optional[int], tokens: Tuple[int, ...]) -> int:
    """Position-chained content hash of one full block of tokens."""
    return hash((parent, tokens))


@dataclasses.dataclass
class PrefixStats:
    lookups: int = 0
    hit_tokens: int = 0
    lookup_tokens: int = 0
    collisions: int = 0


@dataclasses.dataclass
class _CacheEntry:
    bid: int
    parent: Optional[int]
    tokens: Tuple[int, ...]


class PrefixCache:
    """Token-hash-chain map from full prompt blocks to resident pool blocks.

    ``match`` walks the chain of *full* blocks of a token sequence and
    returns the longest resident run; every step re-verifies the stored
    tokens (and parent link) so a Python-hash collision is a recorded miss,
    never a silent wrong-prefix hit.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self._by_hash: Dict[int, _CacheEntry] = {}
        self.stats = PrefixStats()
        pool.on_evict = self._forget

    def _forget(self, bid: int, h: Optional[int]) -> None:
        if h is not None and self._by_hash.get(h, None) is not None \
                and self._by_hash[h].bid == bid:
            del self._by_hash[h]

    def match(self, tokens: Sequence[int],
              max_blocks: Optional[int] = None) -> List[int]:
        """Longest chain of resident full blocks covering a prefix of
        ``tokens``. Returns their block ids (refcounts NOT taken — the
        caller claims them with ``pool.ref_inc`` while it still holds the
        admission lock, i.e. synchronously)."""
        bs = self.pool.block_size
        n_full = len(tokens) // bs
        if max_blocks is not None:
            n_full = min(n_full, max_blocks)
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        hits: List[int] = []
        parent: Optional[int] = None
        for j in range(n_full):
            blk = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            h = chain_hash(parent, blk)
            e = self._by_hash.get(h)
            if e is None:
                break
            if e.tokens != blk or e.parent != parent:
                self.stats.collisions += 1
                break
            hits.append(e.bid)
            self.pool.touch(e.bid)
            parent = h
        self.stats.hit_tokens += len(hits) * bs
        return hits

    def insert(self, tokens: Sequence[int], bids: Sequence[int]) -> None:
        """Register every full block of ``tokens`` (held in ``bids``) for
        future sharing. Already-registered chain links are left in place."""
        bs = self.pool.block_size
        parent: Optional[int] = None
        for j in range(len(tokens) // bs):
            if j >= len(bids):
                break
            blk = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            h = chain_hash(parent, blk)
            e = self._by_hash.get(h)
            if e is None or e.tokens != blk or e.parent != parent:
                if e is not None:
                    self.stats.collisions += 1
                self._by_hash[h] = _CacheEntry(bid=int(bids[j]),
                                               parent=parent, tokens=blk)
                self.pool.register(int(bids[j]), h)
            parent = h

    @property
    def cached_blocks(self) -> int:
        return len(self._by_hash)
