"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = jnp.zeros((b, cfg.frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_tokens"] = jnp.ones((b, 16), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, rep = model.logits(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert int(rep.detected.sum()) == 0  # no FT false positives


@pytest.mark.parametrize("arch", ["gpt2", "hymba-1.5b", "arctic-480b"])
def test_one_train_step(arch):
    from repro.optim import AdamW
    from repro.train import init_state, make_train_step
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt))
    batch = make_batch(cfg)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state.params), jax.tree.leaves(state2.params)))
    assert delta > 0
