"""EFTA — fused fault-tolerant flash attention as a Pallas TPU kernel.

This is the TPU-native artifact of the paper: attention computation and the
hybrid fault-tolerance scheme (tensor-checksum ABFT + SNVR + unified
verification, Algorithm 1) execute inside ONE kernel; the O(n²) score and
probability tiles never leave VMEM.

Architecture mapping (DESIGN.md §2):
  * grid = (batch·heads, Sq/Br, Skv/Bc); the KV axis is ``arbitrary``
    (sequential) so running (m, ℓ, O, O_checksums) accumulate in VMEM scratch
    across KV steps — the Pallas analogue of the paper's intra-CTA loop.
  * checksum folds use *static strided slices* at lane-tile boundaries
    (``s = 128`` → each fold term is a whole-vreg add; ``s = 8`` reproduces the
    paper's MMA-atom stride for fidelity experiments).
  * fault injection is a scalar-prefetch descriptor (SEU model): a single bit
    of a chosen tile element is XOR-flipped at a chosen (site, kv-block).

Validated against ``repro.kernels.ref`` in interpret mode (CPU); the same
code lowers for TPU via Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.efta import EFTAConfig, MASK_VALUE
from repro.core.fault import Site

# renamed TPUCompilerParams -> CompilerParams across pallas versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

# fault descriptor layout (int32[8]):
# [site, kv_block, bh, row, col, bit, enabled, _pad]
F_SITE, F_BLOCK, F_BH, F_ROW, F_COL, F_BIT, F_ON = range(7)


def _flip(tile, *, on, row, col, bit):
    """XOR-flip one bit of tile[row, col] when ``on`` — fully vectorized."""
    rows = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    hit = (rows == row) & (cols == col) & on
    ubits = jax.lax.bitcast_convert_type(tile, jnp.uint32)
    mask = jnp.where(hit, jnp.left_shift(jnp.uint32(1), bit.astype(jnp.uint32)),
                     jnp.uint32(0))
    return jax.lax.bitcast_convert_type(ubits ^ mask, tile.dtype)


def _site_hit(fault_ref, site: Site, *, bh, blk):
    return ((fault_ref[F_ON] == 1)
            & (fault_ref[F_SITE] == int(site))
            & (fault_ref[F_BH] == bh)
            & (fault_ref[F_BLOCK] == blk))


def _fold_slices(tile, stride: int, weighted: bool):
    """Strided fold along the last dim via static lane-tile slices.

    tile: (R, W) -> (R, stride). Each term is a whole-tile add when
    ``stride % 128 == 0`` — the TPU analogue of the paper's intra-thread
    strided accumulation (zero cross-lane shuffles).
    """
    w = tile.shape[-1]
    g = w // stride
    acc = jnp.zeros((tile.shape[0], stride), jnp.float32)
    for l in range(g):
        seg = tile[:, l * stride:(l + 1) * stride].astype(jnp.float32)
        acc = acc + (float(l + 1) * seg if weighted else seg)
    return acc


def _fold_prod(tile, stride: int):
    w = tile.shape[-1]
    g = w // stride
    acc = jnp.ones((tile.shape[0], stride), jnp.float32)
    for l in range(g):
        acc = acc * tile[:, l * stride:(l + 1) * stride].astype(jnp.float32)
    return acc


def _correct_strided(tile, d1, d2, bad, stride: int):
    """Locate (segment l* from the weighted/unweighted delta ratio) and add
    the delta back — paper §4.1 correction, vectorized per fold segment."""
    g = tile.shape[-1] // stride
    safe = jnp.where(bad, d1, 1.0)
    l_star = jnp.clip(jnp.round(d2 / safe) - 1, 0, g - 1).astype(jnp.int32)
    out = tile
    for l in range(g):
        patch = jnp.where(bad & (l_star == l), d1, 0.0)
        seg = out[:, l * stride:(l + 1) * stride] + patch
        out = jax.lax.dynamic_update_slice(out, seg, (0, l * stride))
    return out


def _efta_kernel(
    # scalar prefetch
    fault_ref,
    # inputs
    q_ref, k_ref, v_ref,
    # outputs
    o_ref, rep_ref,
    # scratch
    m_scr, l_scr, lsh_scr, r_scr, acc_scr, oc1_scr, oc2_scr, det_scr,
    vmax_scr,
    *,
    sm_scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_kv: int,
    n_kv: int,
    kv_seq_len: int,
    s_kv: int,
    s_out: int,
    mode: str,
    unified: bool,
    shadow_rowsum: bool,
    shadow_rowmax: bool,
    eps1: float,
    eps2: float,
    eps3: float,
):
    bh = pl.program_id(0)
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    ft = mode != "off"
    correct = mode == "correct"
    g_kv = block_kv // s_kv

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        lsh_scr[...] = jnp.zeros_like(lsh_scr)
        r_scr[...] = jnp.zeros_like(r_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        oc1_scr[...] = jnp.zeros_like(oc1_scr)
        oc2_scr[...] = jnp.zeros_like(oc2_scr)
        det_scr[0] = 0
        det_scr[1] = 0
        det_scr[2] = 0
        det_scr[3] = 0
        det_scr[4] = 0
        vmax_scr[0] = 0.0

    # Causal block skipping: KV blocks strictly above the diagonal contribute
    # nothing — skip their MXU work entirely (flash-attention-2 style).
    q_start = iq * block_q
    kv_start = jk * block_kv
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window is not None:
        run = run & (q_start - (kv_start + block_kv - 1) < window)
    if kv_seq_len < n_kv * block_kv:
        # ragged KV: blocks entirely past the valid prefix are all-masked
        run = run & (kv_start < kv_seq_len)

    @pl.when(run)
    def _body():
        q = q_ref[...]                      # (Br, D)
        k = k_ref[...]                      # (Bc, D)
        v = v_ref[...]                      # (Bc, D)
        if ft:
            # running max|V| across KV blocks: the convex-combination bound
            # |O/l| <= max|V| used by the finalize-stage NVR restriction
            vmax_scr[0] = jnp.maximum(
                vmax_scr[0], jnp.max(jnp.abs(v.astype(jnp.float32))))

        # ---- GEMM I on the MXU (bf16 in, f32 accumulate) + ABFT ----------
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale      # (Br, Bc)
        fault_row = fault_ref[F_ROW] - q_start
        s = _flip(s, on=_site_hit(fault_ref, Site.GEMM1, bh=bh, blk=jk),
                  row=fault_row, col=fault_ref[F_COL], bit=fault_ref[F_BIT])
        if ft:
            # NVR range restriction on scores: keeps the weighted fold finite
            # under exponent-bit corruptions (location ratio stays exact);
            # NaN/inf zero out and the checksum delta restores them exactly.
            s = jnp.where(jnp.isfinite(s), jnp.clip(s, -1e6, 1e6), 0.0)

        if ft:
            # CCG: tensor checksums of K (strided fold along the key axis is
            # a fold along *rows* of K — sublane adds), then one skinny GEMM.
            g = block_kv // s_kv
            kc1 = jnp.zeros((s_kv, k.shape[-1]), jnp.float32)
            kc2 = jnp.zeros((s_kv, k.shape[-1]), jnp.float32)
            for l in range(g):
                seg = k[l * s_kv:(l + 1) * s_kv, :].astype(jnp.float32)
                kc1 = kc1 + seg
                kc2 = kc2 + float(l + 1) * seg
            sc1 = jax.lax.dot_general(
                q.astype(jnp.float32), kc1, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * sm_scale  # (Br, s_kv)
            sc2 = jax.lax.dot_general(
                q.astype(jnp.float32), kc2, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * sm_scale
            sum1 = _fold_slices(s, s_kv, weighted=False)
            sum2 = _fold_slices(s, s_kv, weighted=True)
            d1 = sc1 - sum1
            d2 = sc2 - sum2
            bad = jnp.abs(d1) > eps1
            det_scr[0] += bad.sum(dtype=jnp.int32)
            if correct:
                s = _correct_strided(s, d1, d2, bad, s_kv)

        # ---- mask, running max ------------------------------------------
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < kv_seq_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= rows - cols < window
        s_m = jnp.where(mask, s, MASK_VALUE)
        blockmax = jnp.max(s_m, axis=1, keepdims=True)          # (Br, 1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, blockmax)
        m_new = _flip(m_new, on=_site_hit(fault_ref, Site.ROWMAX, bh=bh, blk=jk),
                      row=fault_row, col=jnp.int32(0), bit=fault_ref[F_BIT])
        if ft and shadow_rowmax:
            m_chk = jnp.maximum(jax.lax.optimization_barrier(m_prev), blockmax)
            bad_m = m_new != m_chk
            det_scr[2] += bad_m.sum(dtype=jnp.int32)
            if correct:
                m_new = jnp.where(bad_m, m_chk, m_new)
        m_scr[...] = m_new
        alive = m_new > MASK_VALUE / 2
        m_sub = jnp.where(alive, m_new, 0.0)

        # ---- EXP with checksum reuse (paper Case 2) ----------------------
        cap = 80.0 / g_kv
        p_raw = jnp.exp(jnp.minimum(s - m_sub, cap))
        p_raw = _flip(p_raw, on=_site_hit(fault_ref, Site.EXP, bh=bh, blk=jk),
                      row=fault_row, col=fault_ref[F_COL], bit=fault_ref[F_BIT])
        if ft:
            pc1 = jnp.exp(jnp.minimum(sc1 - g_kv * m_sub, cap * g_kv))
            prod = _fold_prod(p_raw, s_kv)
            ref = jnp.maximum(jnp.abs(pc1), 1e-20)
            bad_e = jnp.abs(prod - pc1) > eps2 * ref + 1e-20
            capped = (s - m_sub) > (cap - 1e-3)
            col_ok = jnp.ones((s.shape[0], s_kv), dtype=bool)
            for l in range(g_kv):
                col_ok &= ~capped[:, l * s_kv:(l + 1) * s_kv]
            bad_e &= col_ok
            det_scr[1] += bad_e.sum(dtype=jnp.int32)
            if correct:
                recomputed = jnp.exp(jnp.minimum(s - m_sub, cap))
                for l in range(g_kv):
                    seg = jnp.where(
                        bad_e, recomputed[:, l * s_kv:(l + 1) * s_kv],
                        p_raw[:, l * s_kv:(l + 1) * s_kv])
                    p_raw = jax.lax.dynamic_update_slice(
                        p_raw, seg, (0, l * s_kv))
        if ft and shadow_rowmax and correct:
            # Exact recompute backstop (beyond-paper, mirrors the jnp path):
            # EXP corruptions whose fold product underflows (g_kv segments of
            # e^{s-m} can reach 0 in f32) slip the product check, and the
            # NVR clamp alone only bounds the damage. The recompute is
            # already materialized for the correction path above, so an
            # exact compare-and-select closes the gap for one VPU pass.
            # Safe only with shadow_rowmax (m is exact).
            recheck = jnp.exp(jnp.minimum(s - m_sub, cap))
            slipped = p_raw != recheck
            det_scr[1] += slipped.sum(dtype=jnp.int32)
            p_raw = jnp.where(slipped, recheck, p_raw)
        p = jnp.where(mask, p_raw, 0.0)

        # ---- rescale + rowsum (+ shadow) ---------------------------------
        alpha = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)  # (Br, 1)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        l_new = _flip(l_new, on=_site_hit(fault_ref, Site.ROWSUM, bh=bh, blk=jk),
                      row=fault_row, col=jnp.int32(0), bit=fault_ref[F_BIT])
        l_scr[...] = l_new
        if ft and shadow_rowsum:
            p_sh = jax.lax.optimization_barrier(p)
            lsh_scr[...] = alpha * lsh_scr[...] + jnp.sum(p_sh, axis=1,
                                                          keepdims=True)
        blk_alive = blockmax > MASK_VALUE / 2
        r_scr[...] = alpha * r_scr[...] + jnp.where(
            blk_alive, jnp.exp(blockmax - m_sub), 0.0)

        # ---- GEMM II + rescale, checksums carried (Alg.1 l.18-21) --------
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (Br, D)
        acc_new = alpha * acc_scr[...] + pv
        acc_new = _flip(acc_new, on=_site_hit(fault_ref, Site.GEMM2, bh=bh, blk=jk),
                        row=fault_row, col=fault_ref[F_COL], bit=fault_ref[F_BIT])
        acc_scr[...] = acc_new
        if ft:
            g2 = v.shape[-1] // s_out
            vc1 = jnp.zeros((v.shape[0], s_out), jnp.float32)
            vc2 = jnp.zeros((v.shape[0], s_out), jnp.float32)
            for l in range(g2):
                seg = v[:, l * s_out:(l + 1) * s_out].astype(jnp.float32)
                vc1 = vc1 + seg
                vc2 = vc2 + float(l + 1) * seg
            pf = p.astype(jnp.float32)
            oc1_scr[...] = alpha * oc1_scr[...] + jax.lax.dot_general(
                pf, vc1, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            oc2_scr[...] = alpha * oc2_scr[...] + jax.lax.dot_general(
                pf, vc2, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            if not unified:
                # Unoptimized EFTA: verify the output checksum at EVERY kv
                # step (Tables 1-2 compare this against unified verification).
                s1 = _fold_slices(acc_scr[...], s_out, weighted=False)
                d1o = oc1_scr[...] - s1
                det_scr[4] += (jnp.abs(d1o) > eps3).sum(dtype=jnp.int32)

    # ---- finalize: SNVR on ℓ + unified output verification ---------------
    @pl.when(jk == n_kv - 1)
    def _finalize():
        l_f = l_scr[...]
        r_f = r_scr[...]
        if ft:
            upper = float(kv_seq_len) + 1e-3
            in_range = (l_f >= r_f - 1e-3) & (l_f <= upper) & jnp.isfinite(l_f)
            if shadow_rowsum:
                lsh = lsh_scr[...]
                mism = jnp.abs(l_f - lsh) > 1e-5 * jnp.maximum(jnp.abs(lsh), 1e-6)
                bad_l = ((~in_range) | mism) & (r_f > 0)
                fb_ok = (lsh >= r_f - 1e-3) & (lsh <= upper) & jnp.isfinite(lsh)
                fallback = jnp.where(fb_ok, lsh, r_f)
            else:
                bad_l = (~in_range) & (r_f > 0)
                fallback = r_f
            det_scr[3] += bad_l.sum(dtype=jnp.int32)
            if correct:
                l_f = jnp.where(bad_l, fallback, l_f)
        l_safe = jnp.where(l_f == 0.0, 1.0, l_f)
        o = acc_scr[...] / l_safe
        if ft:
            if correct:
                # NVR range restriction: O/l is a convex combination of V
                # rows, so |o| <= max|V|. Zero violations (incl. NaN/inf)
                # so the output-checksum delta restores the exact value —
                # otherwise a 1e38-scale accumulator corruption cancels
                # catastrophically in the correction add.
                bound = vmax_scr[0] * 1.001 + 1e-6
                o = jnp.where(jnp.isfinite(o) & (jnp.abs(o) <= bound),
                              o, 0.0)
            oc1 = oc1_scr[...] / l_safe
            oc2 = oc2_scr[...] / l_safe
            s1 = _fold_slices(o, s_out, weighted=False)
            s2 = _fold_slices(o, s_out, weighted=True)
            d1 = oc1 - s1
            d2 = oc2 - s2
            bad = ~(jnp.abs(d1) <= eps3)   # NaN-safe (detect mode)
            det_scr[4] += bad.sum(dtype=jnp.int32)
            if correct:
                o = _correct_strided(o, d1, d2, bad, s_out)
        o_ref[...] = o.astype(o_ref.dtype)
        rep_ref[0] = det_scr[0]
        rep_ref[1] = det_scr[1]
        rep_ref[2] = det_scr[2]
        rep_ref[3] = det_scr[3]
        rep_ref[4] = det_scr[4]


def efta_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    cfg: EFTAConfig,
    causal: bool = False,
    window: Optional[int] = None,
    kv_len: Optional[int] = None,
    sm_scale: Optional[float] = None,
    fault: Optional[jax.Array] = None,
    block_q: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused EFTA kernel. q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D).

    Returns (out (B, H, Sq, D), detected (4,) int32).
    ``fault``: int32[8] SEU descriptor (see module docstring) or None.
    ``kv_len`` (static int) masks a ragged KV tail: only the first ``kv_len``
    of the ``Skv`` cache slots are attended (serving caches are allocated at
    block-aligned capacity but only partially filled). It also tightens the
    SNVR rowsum bound to the number of *valid* keys.
    ``interpret=True`` validates on CPU; on TPU pass False.
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    grp = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    if kv_len is None:
        kv_len = skv
    if not 0 < kv_len <= skv:
        raise ValueError(f"kv_len {kv_len} out of range (0, {skv}]")

    block_q = min(block_q, sq)
    block_kv = min(cfg.block_kv, skv)
    if sq % block_q or skv % block_kv:
        raise ValueError(f"seq lens ({sq},{skv}) must divide blocks "
                         f"({block_q},{block_kv})")
    s_kv = cfg.kv_stride(block_kv)
    s_out = cfg.out_stride(d)
    eps1, eps2, eps3 = cfg.thresholds(q.dtype)
    n_q, n_kv = sq // block_q, skv // block_kv

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)
    if fault is None:
        fault = jnp.zeros((8,), jnp.int32)

    kernel = functools.partial(
        _efta_kernel,
        sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=n_kv, kv_seq_len=kv_len,
        s_kv=s_kv, s_out=s_out, mode=cfg.mode, unified=cfg.unified,
        shadow_rowsum=cfg.shadow_rowsum, shadow_rowmax=cfg.shadow_rowmax,
        eps1=eps1, eps2=eps2, eps3=eps3)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, i, j, f: (bh, i, 0)),
            pl.BlockSpec((None, block_kv, d),
                         lambda bh, i, j, f, g=grp: (bh // g, j, 0)),
            pl.BlockSpec((None, block_kv, d),
                         lambda bh, i, j, f, g=grp: (bh // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, i, j, f: (bh, i, 0)),
            pl.BlockSpec((None, None, 5), lambda bh, i, j, f: (bh, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, 1), jnp.float32),   # l shadow
            pltpu.VMEM((block_q, 1), jnp.float32),   # r (SNVR bound)
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q, s_out), jnp.float32),  # O checksum 1
            pltpu.VMEM((block_q, s_out), jnp.float32),  # O checksum 2
            pltpu.SMEM((5,), jnp.int32),             # detection counters
            pltpu.SMEM((1,), jnp.float32),           # running max|V| (NVR)
        ],
    )

    out, rep = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, n_q, 5), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(fault, qr, kr, vr)

    return out.reshape(b, h, sq, d), rep.sum(axis=(0, 1))
