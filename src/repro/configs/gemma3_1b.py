"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 —
5:1 local(sliding-window 512):global, 128k-class context, head_dim 256.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, d_ff=6912, vocab_size=262144,
    attn=AttnCfg(num_heads=4, num_kv_heads=1, head_dim=256,
                 sliding_window=512, global_every=6),
    source="hf:google/gemma-3-1b-pt",
)
