"""Tiny structured logger (no external deps)."""
from __future__ import annotations

import logging
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        h = logging.StreamHandler(sys.stderr)
        h.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(h)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    return logger
