"""Per-request token sampling for the serve engine.

Everything here is jit-friendly at fixed batch shape: per-request sampling
parameters ride along as arrays (temperature, top-k, PRNG key per row), so one
compiled ``sample_tokens`` serves an arbitrary mix of greedy and stochastic
requests in the same batch. ``temperature == 0`` rows take the exact
``argmax`` path (bit-identical to the sequential greedy decoder).

:func:`speculative_accept` is the *accept* stage of the engine's
propose→score→accept contract: standard speculative rejection sampling over
the target's per-row logits, run host-side on the scored chunk. Greedy
requests take the exact-argmax path, which is what makes greedy speculative
decoding token-identical to the non-speculative engine (the parity oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (host-side)."""

    temperature: float = 0.0   # 0 => greedy (exact argmax)
    top_k: int = 0             # 0 => no truncation
    seed: int = 0              # per-request PRNG stream

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")


def request_key(params: SamplingParams, rid: int) -> jax.Array:
    """Stable per-request PRNG key: independent streams even when two
    requests share a seed."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), rid)


def _top_k_mask(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Mask logits below each row's k-th largest value. ``top_k`` (B,) int32;
    0 disables truncation for that row (k clamps to the full vocab)."""
    vocab = logits.shape[-1]
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, vocab), vocab)
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    return jnp.where(logits >= kth, logits, -jnp.inf)


def sample_tokens(logits: jax.Array, *, temperature: jax.Array,
                  top_k: jax.Array, keys: jax.Array) -> jax.Array:
    """Sample one token per row. logits (B, V) f32; temperature (B,) f32;
    top_k (B,) int32; keys (B,) PRNG keys. Returns (B,) int32.

    Stochastic rows use the Gumbel-max trick (exactly equivalent to
    categorical sampling over the top-k-truncated, temperature-scaled
    distribution); greedy rows bypass noise entirely.
    """
    greedy = temperature <= 0.0
    masked = _top_k_mask(logits, top_k)
    t_safe = jnp.where(greedy, 1.0, jnp.maximum(temperature, 1e-6))
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, logits.shape[-1:],
                                                  jnp.float32))(keys)
    stochastic = jnp.argmax(masked / t_safe[:, None] + gumbel, axis=-1)
    return jnp.where(greedy, jnp.argmax(logits, axis=-1),
                     stochastic).astype(jnp.int32)


# ---------------------------------------------------------------------------
# speculative decoding: the accept stage (host-side, numpy)
# ---------------------------------------------------------------------------

def target_probs(row: np.ndarray, *, temperature: float,
                 top_k: int) -> np.ndarray:
    """The target distribution one logits row samples from: top-k truncation
    then temperature-scaled softmax — exactly the distribution
    :func:`sample_tokens`'s Gumbel-max draw is equivalent to."""
    row = np.asarray(row, np.float64)
    if top_k > 0:
        kth = np.sort(row)[-min(top_k, row.size)]
        row = np.where(row >= kth, row, -np.inf)
    t = max(float(temperature), 1e-6)
    z = row / t
    z = z - np.max(z)
    p = np.exp(z)
    return p / p.sum()


def speculative_accept(
    rows: np.ndarray,
    draft: np.ndarray,
    *,
    temperature: float,
    top_k: int,
    rng: Optional[np.random.Generator] = None,
    q_probs: Optional[np.ndarray] = None,
) -> Tuple[int, int]:
    """Standard speculative rejection sampling against the target logits.

    ``rows``: (k+1, V) target logits for the scored chunk — row ``j`` is
    ``p(. | context, accepted rows 0..j-1)``, i.e. the distribution draft
    token ``draft[j]`` was proposed for; row ``k`` conditions on all k
    drafts and supplies the bonus token when every draft is accepted.
    ``q_probs`` (k,) optionally gives the proposer's probability of each
    draft token (default 1.0: a deterministic one-hot proposer — the
    n-gram/prompt-lookup case, or a greedy draft model). The residual
    distribution is computed assuming the proposer's mass is concentrated
    on the proposed token (exact for the one-hot proposers shipped here;
    an arbitrary stochastic proposer would need its full q vector).

    Returns ``(n_accepted, next_token)``: the longest accepted draft prefix
    and the token sampled after it (the bonus token from row
    ``n_accepted`` when all drafts were accepted, else the residual-
    distribution resample at the rejection row). The committed tokens are
    ``draft[:n_accepted] + [next_token]`` — by the standard argument each
    committed token is distributed exactly as a non-speculative sample from
    the target, so speculation changes throughput, never the distribution.

    ``temperature <= 0`` is the exact greedy path: accept ``draft[j]`` iff
    it equals ``argmax(rows[j])``, bonus/resample by argmax — token-
    identical to the non-speculative greedy engine.
    """
    rows = np.asarray(rows, np.float32)
    draft = np.asarray(draft, np.int64).reshape(-1)
    k = draft.size
    assert rows.shape[0] >= k + 1, (rows.shape, k)

    if temperature <= 0.0:
        n = 0
        while n < k and int(np.argmax(rows[n])) == int(draft[n]):
            n += 1
        return n, int(np.argmax(rows[n]))

    assert rng is not None, "stochastic acceptance needs a PRNG"
    n = 0
    while n < k:
        p = target_probs(rows[n], temperature=temperature, top_k=top_k)
        q = 1.0 if q_probs is None else float(q_probs[n])
        if rng.uniform() < p[draft[n]] / max(q, 1e-20):
            n += 1
            continue
        # rejected: resample from the residual max(p - q, 0) renormalized.
        # For a one-hot proposal this is p with the draft token zeroed.
        res = p.copy()
        if q_probs is None:
            res[draft[n]] = 0.0
        else:
            res[draft[n]] = max(res[draft[n]] - q, 0.0)
        s = res.sum()
        if s <= 0.0:          # proposal == target mass; degenerate residual
            return n, int(draft[n])
        return n, int(rng.choice(res.size, p=res / s))
    p = target_probs(rows[k], temperature=temperature, top_k=top_k)
    return k, int(rng.choice(p.size, p=p))
