"""Distribution: sharding rules, small-mesh SPMD train step, compressed
collectives. Runs in a subprocess with 8 forced host devices so the main
test process keeps its single-device view."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed import quantize_int8, spec_for_param
from repro.distributed.collectives import dequantize_int8


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    assert spec_for_param("blocks/attn/wq", 3) == P(None, "data", "model")
    assert spec_for_param("blocks/attn/wo", 2) == P("model", "data")
    assert spec_for_param("blocks/moe/wg", 3) == P("model", "data", None)
    assert spec_for_param("embed/table", 2) == P("model", None)
    assert spec_for_param("blocks/norm1/w", 1) == P(None)


def test_int8_quant_roundtrip():
    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.standard_normal((128,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.51 + 1e-6  # within half a quantization step


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.train import init_state, make_train_step
    from repro.distributed.sharding import param_shardings, batch_sharding

    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("pod", "data", "model"))
    cfg = get_config("arctic-480b-smoke")
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    pshard = param_shardings(jax.eval_shape(lambda: state.params), mesh)
    state = state._replace(
        params=jax.device_put(state.params, pshard),
        opt=state.opt._replace(m=jax.device_put(state.opt.m, pshard),
                               v=jax.device_put(state.opt.v, pshard)))
    step = jax.jit(make_train_step(model, opt, mesh=mesh))
    batch = {
        "tokens": jax.device_put(jnp.ones((8, 32), jnp.int32),
                                 batch_sharding(mesh, 2)),
        "targets": jax.device_put(jnp.ones((8, 32), jnp.int32),
                                  batch_sharding(mesh, 2)),
    }
    with mesh:
        state, metrics = step(state, batch)
        state, metrics = step(state, batch)
    print(json.dumps({"loss": float(metrics["loss"]),
                      "finite": bool(jnp.isfinite(metrics["loss"]))}))
""")


def test_spmd_moe_train_step_8dev():
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        timeout=560, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["finite"]


COMPRESSED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.collectives import compressed_psum
    from repro.utils.compat import shard_map

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("pod", "data"))
    x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0
    err = jnp.zeros((1, 8), jnp.float32)

    def f(x, err):
        return compressed_psum(x, "pod", err)

    y, new_err = shard_map(
        f, mesh=mesh, in_specs=(P("pod", "data"), P(None, "data")),
        out_specs=(P(None, "data"), P(None, "data")), check_vma=False)(x, err)
    ref = np.asarray(x).reshape(4, 1, 8).mean(0)
    got = np.asarray(y)[:1]
    print(json.dumps({"max_err": float(np.abs(got - ref).max()),
                      "scale": float(np.abs(ref).max())}))
""")


def test_compressed_psum_8dev():
    out = subprocess.run(
        [sys.executable, "-c", COMPRESSED], capture_output=True, text=True,
        timeout=560, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["max_err"] <= 0.05 * max(res["scale"], 1e-6) + 0.05
