"""Paper Tables 1-2: EFTA (per-block verification) vs optimized EFTA-o
(unified verification) across sequence lengths, two head settings.

NOTE: the paper measures 1.32x on A100 where per-block verification forces
extra tensor-core pipeline flushes; on the CPU host the per-block check is a
small fused fold (wall-clock delta within noise) — the structural work delta
is nblk-1 extra fold-verifications per row, visible in the HLO op counts."""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, qkv, time_fn
from repro.core import EFTAConfig
from repro.core.efta import efta_attention

TOTAL_TOKENS = 2048


def run():
    rows = []
    for heads, dim, label in [(4, 64, "medium"), (8, 128, "large")]:
        for seq in (256, 512, 1024):
            b = max(TOTAL_TOKENS // seq, 1)
            q, k, v = qkv(b, heads, heads, seq, dim, jnp.float32)
            base = time_fn(jax.jit(functools.partial(
                efta_attention, cfg=EFTAConfig(mode="off", block_kv=128))),
                q, k, v)
            t_step = time_fn(jax.jit(functools.partial(
                efta_attention,
                cfg=EFTAConfig(mode="correct", stride=16, block_kv=128,
                               unified=False))), q, k, v)
            t_uni = time_fn(jax.jit(functools.partial(
                efta_attention,
                cfg=EFTAConfig(mode="correct", stride=16, block_kv=128,
                               unified=True))), q, k, v)
            rows.append({
                "name": f"{label}_seq{seq}_efta", "us": t_step * 1e6,
                "derived": f"oh={(t_step-base)/base*100:.1f}%"})
            rows.append({
                "name": f"{label}_seq{seq}_efta_o", "us": t_uni * 1e6,
                "derived": (f"oh={(t_uni-base)/base*100:.1f}%"
                            f";speedup={t_step/t_uni:.2f}x")})
    emit(rows, "Tables 1-2: unified verification (EFTA-o) vs per-block EFTA")
    return rows


if __name__ == "__main__":
    run()
