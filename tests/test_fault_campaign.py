"""Deterministic randomized SEU campaign (paper §5.3), promoted from
``examples/fault_injection_campaign.py`` into tier-1: ~50 seeded faults per
mode across every attention site, asserting per-site detection/correction
coverage. Shares ``repro.core.campaign`` with the example script."""
import numpy as np
import pytest

from repro.core import DEFAULT_SITES, Site, run_campaign

pytestmark = pytest.mark.quick

N = 50
BITS = (20, 30)  # high bits: corruptions visible above the damage tolerance


@pytest.fixture(scope="module")
def correct_result():
    return run_campaign(mode="correct", n_trials=N, seed=0, bit_range=BITS)


def test_correct_mode_no_silent_corruption(correct_result):
    t = correct_result.totals
    assert t.trials == N
    assert t.silent == 0, correct_result.format_table()
    # everything visibly corrupt was also repaired, not just flagged
    assert t.detected == 0, correct_result.format_table()
    assert correct_result.worst_residual < 1e-3


def test_correct_mode_per_site_coverage(correct_result):
    for site in DEFAULT_SITES:
        tally = correct_result.per_site[site]
        assert tally.trials > 0, f"campaign never sampled {site.name}"
        assert tally.silent == 0, f"{site.name}: {tally}"
    # the ABFT/SNVR sites must show real corrections (not all-harmless):
    # ROWMAX is excluded — its errors cancel analytically (paper Case 1)
    for site in (Site.GEMM1, Site.EXP, Site.ROWSUM, Site.GEMM2):
        assert correct_result.per_site[site].corrected > 0, site.name


def test_detect_mode_flags_every_corruption():
    r = run_campaign(mode="detect", n_trials=N, seed=0, bit_range=BITS)
    assert r.totals.silent == 0, r.format_table()
    # detect mode never repairs: visible corruptions stay in the output
    assert r.totals.detected > 0


def test_off_mode_suffers_silent_corruption():
    """Sanity: the same faults visibly corrupt an unprotected run."""
    r = run_campaign(mode="off", n_trials=20, seed=0, bit_range=BITS)
    assert r.totals.silent > 0
    assert r.totals.corrected == 0 and r.totals.detected == 0


def test_campaign_is_deterministic():
    a = run_campaign(mode="correct", n_trials=10, seed=3, bit_range=BITS)
    b = run_campaign(mode="correct", n_trials=10, seed=3, bit_range=BITS)
    assert a.per_site == b.per_site
    assert np.isclose(a.worst_residual, b.worst_residual)


def test_fused_kernel_kv_campaign_no_silent_resident_corruption():
    """Site.KV SEU campaign through the *fused* paged-attention backend:
    every randomized resident-KV high-bit flip must be caught by the
    kernel's in-loop verify (or the append-time tail check), healed by
    block re-prefill, and leave every request token-identical to the clean
    run — the same zero-silent-corruption bar the gather backend holds."""
    from repro.core import run_kv_campaign
    r = run_kv_campaign(n_trials=4, seed=5, kernel="fused", n_requests=2,
                        cache_len=48, gen=6)
    assert r.n_trials == 4
    assert r.detected == 4, r.format_table()
    assert r.undetected == 0
    assert r.repaired_blocks >= 4
    assert r.mismatched_requests == 0, r.format_table()
    assert r.telemetry_kv_detected == 4
