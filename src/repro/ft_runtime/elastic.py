"""Elastic re-meshing: plan a new mesh when the healthy device set changes.

A real deployment feeds this from the cluster manager's health service; the
planning logic is pure and tested here. Policy: keep the ``model`` axis at
its configured size (TP degree is baked into weight shards), shrink the
``data``(/``pod``) axes to the largest supported DP degree, and resume from
the latest checkpoint (restore() reshards automatically; the stateless data
pipeline needs only the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    dropped_devices: int
    world: int

    @property
    def dp_degree(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("pod", "data"):
                n *= s
        return n


def plan_mesh(n_healthy: int, *, model_parallel: int = 16,
              prefer_pods: bool = True) -> Optional[MeshPlan]:
    """Largest mesh with a fixed TP degree that fits the healthy devices."""
    if n_healthy < model_parallel:
        return None
    dp = n_healthy // model_parallel
    if prefer_pods and dp >= 32 and dp % 16 == 0:
        pods = dp // 16
        return MeshPlan((pods, 16, model_parallel), ("pod", "data", "model"),
                        n_healthy - pods * 16 * model_parallel,
                        pods * 16 * model_parallel)
    return MeshPlan((dp, model_parallel), ("data", "model"),
                    n_healthy - dp * model_parallel, dp * model_parallel)


def build_mesh(plan: MeshPlan, devices=None):
    import jax
    from jax.sharding import Mesh
    devices = devices if devices is not None else jax.devices()
    use = np.asarray(devices[: plan.world]).reshape(plan.shape)
    return Mesh(use, plan.axes)
