"""Paper Fig. 12: error coverage + false-alarm analysis of tensor-checksum
ABFT under random single-bit flips, across detection thresholds and strides.

Also characterizes the documented EXP-product-check underflow blindspot
(DESIGN.md) and the layered NVR clamp that bounds its damage."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, qkv
from repro.core import EFTAConfig, FaultSpec, Site
from repro.core.efta import efta_attention, reference_attention

B, H, S, D = 1, 2, 128, 32
N_TRIALS = 60


def campaign(cfg, sites, bits, seed=0):
    q, k, v = qkv(B, H, H, S, D, jnp.float32, seed=seed)
    ref = reference_attention(q, k, v)
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    rng = np.random.default_rng(seed)
    detected = harmful = caught_harmful = false_alarm = 0
    max_resid = 0.0
    # clean run -> false alarms
    _, rep0 = fn(q, k, v)
    false_alarm += int(np.sum(np.asarray(rep0.detected)))
    for t in range(N_TRIALS):
        f = FaultSpec.single(
            Site(int(rng.choice([int(s) for s in sites]))),
            block=int(rng.integers(0, S // cfg.block_kv)),
            batch=0, head=int(rng.integers(0, H)),
            row=int(rng.integers(0, S)), col=int(rng.integers(0, S)),
            bit=int(rng.choice(bits)))
        out, rep = fn(q, k, v, fault=f)
        err = float(jnp.max(jnp.abs(out - ref)))
        det = int(np.sum(np.asarray(rep.detected))) > 0
        detected += det
        if err > 1e-3:
            harmful += 1
            caught_harmful += det
        max_resid = max(max_resid, err)
    return dict(detected=detected, harmful=harmful,
                caught_harmful=caught_harmful, false_alarm=false_alarm,
                max_resid=max_resid, trials=N_TRIALS)


def run():
    rows = []
    sites = [Site.GEMM1, Site.EXP, Site.GEMM2]
    high_bits = list(range(23, 31))   # exponent+high-mantissa flips
    all_bits = list(range(0, 31))
    for stride, label in [(8, "paper_s8"), (64, "tpu_s64")]:
        cfg = EFTAConfig(mode="correct", stride=stride, block_kv=32,
                         kv_stride_override=stride if stride <= 16 else None)
        r = campaign(cfg, sites, high_bits)
        rows.append({
            "name": f"{label}_highbits", "us": 0.0,
            "derived": (f"coverage={r['detected']}/{r['trials']}"
                        f";harmful_caught={r['caught_harmful']}/{r['harmful']}"
                        f";false_alarms={r['false_alarm']}"
                        f";max_residual={r['max_resid']:.2e}")})
        r2 = campaign(cfg, sites, all_bits)
        rows.append({
            "name": f"{label}_allbits", "us": 0.0,
            "derived": (f"coverage={r2['detected']}/{r2['trials']}"
                        f";harmful_caught={r2['caught_harmful']}/{r2['harmful']}"
                        f";max_residual={r2['max_resid']:.2e}")})
    # threshold sweep (paper: 0.48 optimal for fp16; we re-derive for f32)
    for eps in (1e-5, 1e-3, 1e-1):
        cfg = EFTAConfig(mode="detect", stride=8, block_kv=32, eps_gemm1=eps)
        r = campaign(cfg, [Site.GEMM1], high_bits)
        rows.append({"name": f"threshold_{eps}", "us": 0.0,
                     "derived": (f"detected={r['detected']}/{r['trials']}"
                                 f";false_alarms={r['false_alarm']}")})
    emit(rows, "Fig12: error coverage / false alarms")
    return rows


if __name__ == "__main__":
    run()
