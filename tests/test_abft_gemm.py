"""ABFT-protected linear layers: traditional vs tensor-checksum variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FaultSpec, Site, abft_matmul, tensor_abft_matmul

pytestmark = pytest.mark.quick


@pytest.mark.parametrize("fn", [abft_matmul, tensor_abft_matmul])
@pytest.mark.parametrize("m,k,n", [(8, 64, 128), (16, 32, 64), (4, 16, 24)])
def test_no_fault_identity(fn, m, k, n):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    y, nd = fn(x, w)
    np.testing.assert_allclose(y, x @ w, atol=1e-4)
    assert int(nd) == 0


@pytest.mark.parametrize("fn", [abft_matmul, tensor_abft_matmul])
def test_fault_corrected(fn):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    f = FaultSpec.single(Site.GEMM1, row=3, col=77, bit=25)
    y, nd = fn(x, w, fault=f)
    assert int(nd) == 1
    np.testing.assert_allclose(y, x @ w, atol=1e-4)


def test_bf16_thresholds_no_false_positive():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((32, 256)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((256, 512)), jnp.bfloat16)
    for fn in (abft_matmul, tensor_abft_matmul):
        _, nd = fn(x, w)
        assert int(nd) == 0
