"""ABFT-protected linear layers (feed-forward / projections).

The paper (§4.1) notes the tensor-checksum encoding "can be extended to
mixed-precision linear operations in the feed-forward layers" — this module is
that extension. Two variants:

  * ``abft_matmul``         — classic rank-1 ABFT (baseline, Fig. 11 purple)
  * ``tensor_abft_matmul``  — strided tensor-checksum ABFT (Fig. 11 orange),
                              fold stride matched to the TPU lane tile

Both protect ``y = x @ w`` where errors are injected into ``y``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import checksum as cks
from repro.core.fault import FaultSpec, Site, inject


def _threshold_for(dtype, override: Optional[float]) -> float:
    # relative to checksum magnitude (see checksum.verify_and_correct)
    if override is not None:
        return override
    return 1e-3 if jnp.dtype(dtype) == jnp.float32 else 5e-2


def abft_matmul(x, w, *, correct: bool = True, threshold: Optional[float] = None,
                fault: Optional[FaultSpec] = None):
    """y = x @ w with classic rank-1 row-checksum ABFT. x: (..., M, K), w: (K, N)."""
    wc = cks.traditional_encode_cols(w)               # (K, 2)
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    y = inject(y, fault, Site.GEMM1, 0)
    y_checks = jnp.matmul(x, wc, preferred_element_type=jnp.float32)
    verdict = cks.traditional_verify_correct(
        y, y_checks, threshold=_threshold_for(x.dtype, threshold), correct=correct)
    return verdict.corrected.astype(x.dtype), verdict.n_detected


def tensor_abft_matmul(x, w, *, stride: int = cks.TPU_STRIDE, correct: bool = True,
                       threshold: Optional[float] = None,
                       fault: Optional[FaultSpec] = None):
    """y = x @ w with strided tensor-checksum ABFT (paper §4.1, TPU layout).

    The checksum folds the output feature axis with stride ``s``; encode and
    verify are whole-vreg adds when ``s % 128 == 0``.
    """
    n = w.shape[-1]
    s = min(stride, max(n // 2, 4))
    wc = cks.encode_cols(w, s)                        # (K, s) x2
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    y = inject(y, fault, Site.GEMM1, 0)
    c1 = jnp.matmul(x, wc.c1, preferred_element_type=jnp.float32)
    c2 = jnp.matmul(x, wc.c2, preferred_element_type=jnp.float32)
    verdict = cks.verify_and_correct(
        y, cks.Checksums(c1, c2), s,
        threshold=_threshold_for(x.dtype, threshold), correct=correct)
    return verdict.corrected.astype(x.dtype), verdict.n_detected
