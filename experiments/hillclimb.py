"""§Perf hillclimb driver: re-lowers the three chosen cells with one change
per iteration and records the roofline-term deltas next to the baseline.

Run AFTER the baseline sweep:
  PYTHONPATH=src python experiments/hillclimb.py [cellA|cellB|cellC ...]

Cells (per the assignment's selection rule):
  A. kimi-k2-1t-a32b x decode_32k x 16x16   — most collective-bound
  B. arctic-480b    x train_4k   x 16x16   — worst memory pressure (0.047 rf)
  C. deepseek-coder-33b x prefill_32k x 16x16 — most representative of the
     paper's technique (EFTA protecting long-sequence inference attention)
"""
import sys
sys.path.insert(0, "src")

import dataclasses
import json
from pathlib import Path

OUT = Path("experiments/dryrun")


def log(r, note):
    t = r["roofline"]
    print(f"  -> {r['tag'] or 'baseline'}: c={t['compute_s']:.2e} "
          f"m={t['memory_s']:.2e} x={t['collective_s']:.2e} "
          f"peak={r['memory']['peak_bytes']/1e9:.1f}GB "
          f"rf={r['roofline_fraction'] and round(r['roofline_fraction'],4)} "
          f"| {note}", flush=True)


def cell_a():
    """kimi decode: hypothesis — per-step FSDP weight gathers dominate the
    collective term; the inference layout (pure-TP dense + fully-sharded
    experts, tokens gathered instead of weights) removes them."""
    from repro.launch.dryrun import cell_config, run_cell
    cfg = cell_config("kimi-k2-1t-a32b", "decode_32k")
    # iter 1: inference parameter layout + decode EP
    cfg1 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, inference_ep=True))
    r = run_cell("kimi-k2-1t-a32b", "decode_32k", multi_pod=False,
                 out_dir=OUT, cfg_override=cfg1, tag="infer_layout",
                 inference_layout=True)
    log(r, "inference layout: no per-step weight gathers")


def cell_b():
    """arctic train: memory-dominant. iter1 microbatching (peak), iter2
    sequence parallelism (residuals / activation traffic), iter3 checksum
    stride ablation (the refuted lane-aligned s=128 hypothesis)."""
    from repro.launch.dryrun import cell_config, run_cell
    cfg = cell_config("arctic-480b", "train_4k")

    r = run_cell("arctic-480b", "train_4k", multi_pod=False, out_dir=OUT,
                 cfg_override=cfg, tag="mb4", microbatches=4)
    log(r, "microbatch=4: activation liveness / peak")

    cfg2 = dataclasses.replace(cfg, seq_parallel=True)
    r = run_cell("arctic-480b", "train_4k", multi_pod=False, out_dir=OUT,
                 cfg_override=cfg2, tag="seqpar", microbatches=4)
    log(r, "sequence parallel + mb4: residuals sharded over model")

    for stride, tag in ((8, "s8_paper"), (128, "s128_lane")):
        cfgs = dataclasses.replace(
            cfg, ft=dataclasses.replace(cfg.ft, stride=stride,
                                        scan_unroll=False))
        # pin fold widths to the stride to expose the width-vs-layout trade
        from repro.configs.base import FTCfg
        r = run_cell("arctic-480b", "train_4k", multi_pod=False, out_dir=OUT,
                     cfg_override=cfgs, tag=tag, microbatches=4)
        log(r, f"checksum stride {stride}: width drives MXU overhead")


def cell_c():
    """deepseek prefill: paper-representative. iter1: Pallas-fused-kernel
    deployment accounting — measure the S/P tile HBM traffic present in the
    XLA (unfused) HLO that the fused kernel keeps in VMEM, and report the
    corrected memory term."""
    import re
    import jax
    from repro.launch.dryrun import (HBM_BW, PEAK_FLOPS, cell_config,
                                     _compile_cell, probe_config, probe_plan,
                                     _costs)
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    cfg = cell_config("deepseek-coder-33b", "prefill_32k")
    k1, k2, n_per = probe_plan(cfg)
    c1 = _compile_cell(probe_config(cfg, k1), "prefill_32k", mesh)[0]
    c2 = _compile_cell(probe_config(cfg, k2), "prefill_32k", mesh)[0]

    def tile_bytes(compiled, sq_loc, bc):
        """Sum result bytes of ops carrying S/P-tile shapes (.., sq, bc) —
        the traffic a fused kernel keeps in VMEM."""
        txt = compiled.as_text()
        total = 0
        pat = re.compile(r"(f32|bf16)\[([0-9,]+)\]")
        for line in txt.splitlines():
            if "= " not in line or "fusion" not in line and "dot" not in line \
                    and "exp" not in line:
                continue
            for m in pat.finditer(line.split("=", 1)[1].split("(", 1)[0]):
                dims = [int(x) for x in m.group(2).split(",")]
                if len(dims) >= 2 and dims[-1] == bc and dims[-2] == sq_loc:
                    n = 1
                    for d_ in dims:
                        n *= d_
                    total += n * (4 if m.group(1) == "f32" else 2)
        return total

    p1, p2 = _costs(c1), _costs(c2)
    flops = p1["flops"] + n_per * (p2["flops"] - p1["flops"])
    bytes_total = p1["bytes"] + n_per * (p2["bytes"] - p1["bytes"])
    sq_loc, bc = 32768, cfg.ft.block_kv
    tb1, tb2 = tile_bytes(c1, sq_loc, bc), tile_bytes(c2, sq_loc, bc)
    tile_total = 2 * (tb1 + n_per * (tb2 - tb1))  # read+write per boundary
    mem_s = bytes_total / HBM_BW
    mem_s_fused = max(bytes_total - tile_total, 0) / HBM_BW
    print(f"  -> kernelized: S/P tile traffic {tile_total/1e9:.1f}GB/device; "
          f"memory term {mem_s:.2e}s -> {mem_s_fused:.2e}s "
          f"(compute term {flops/PEAK_FLOPS:.2e}s)", flush=True)
    Path(OUT / "deepseek-coder-33b__prefill_32k__16x16__kernelized.json"
         ).write_text(json.dumps({
             "arch": "deepseek-coder-33b", "shape": "prefill_32k",
             "mesh": "16x16", "tag": "kernelized",
             "memory_s_baseline": mem_s, "memory_s_fused": mem_s_fused,
             "tile_bytes": tile_total, "flops_per_device": flops,
             "compute_s": flops / PEAK_FLOPS}, indent=2))


if __name__ == "__main__":
    which = sys.argv[1:] or ["cellA", "cellB", "cellC"]
    for w in which:
        print(f"== hillclimb {w} ==", flush=True)
        {"cellA": cell_a, "cellB": cell_b, "cellC": cell_c}[w]()
