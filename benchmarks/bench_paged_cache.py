"""Paged KV-cache: prefix-hit prefill speedup and decode throughput vs ring.

Workload A (prefill): N requests share a long system prompt (96 of 104
tokens). The ring engine re-prefills the full prompt for every request; the
paged engine prefills it once, then serves every later admission from the
prefix cache plus an 8-token suffix ``Model.extend``. The headline number is
``prefill_speedup`` (>= 2x expected at this sharing ratio).

Workload B (decode): same requests, long generation — decode throughput
paged vs ring measures the price of gather-by-block-table + read-time block
checksum verification on the decode path.

Machine-readable results are emitted as ``BENCH {json}`` lines (one per
metric block); CPU-host caveat of benchmarks/common.py applies — ratios are
the metric, not absolute tokens/s.

  PYTHONPATH=src python -m benchmarks.bench_paged_cache
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import PagedServeEngine, ServeEngine

SHARED, TAIL = 96, 8
CACHE_LEN = 128
BLOCK = 16
N_REQ = 6


def _submit_all(eng, prompts, gen):
    for p in prompts:
        eng.submit(p, max_new_tokens=gen)


def _timed_run(eng):
    t0 = time.perf_counter()
    eng.run()
    return time.perf_counter() - t0


def bench_prefill(model, params, rng, vocab):
    """Total admission (prefill) time for N shared-prefix requests."""
    sys_prompt = rng.integers(0, vocab, (SHARED,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, vocab, (TAIL,)).astype(np.int32)])
               for _ in range(N_REQ)]
    # distinct-prefix warmup set: compiles every jit path (full-prefill
    # bucket, suffix extend bucket, gather/scatter, decode) without seeding
    # the measured prefix
    warm_sys = rng.integers(0, vocab, (SHARED,)).astype(np.int32)
    warm = [np.concatenate([warm_sys,
                            rng.integers(0, vocab, (TAIL,)).astype(np.int32)])
            for _ in range(2)]

    def serve(eng):
        # warmup compiles every path; the warm requests run one at a time so
        # the second one takes the prefix-HIT admission path (gather+extend)
        for w in warm:
            eng.submit(w, max_new_tokens=1)
            eng.run()
        # timed: first request pays the one full prefill of the system
        # prompt; the rest arrive after it is resident (staggered arrival,
        # as in real serving) and admit from the prefix cache
        t0 = time.perf_counter()
        eng.submit(prompts[0], max_new_tokens=1)
        eng.run()
        _submit_all(eng, prompts[1:], 1)
        eng.run()
        return time.perf_counter() - t0

    t_ring = serve(ServeEngine(model, params, n_slots=2,
                               cache_len=CACHE_LEN))
    paged = PagedServeEngine(model, params, n_slots=2, cache_len=CACHE_LEN,
                             block_size=BLOCK, num_blocks=64)
    t_paged = serve(paged)

    hit_tokens = paged.pool.prefix.stats.hit_tokens
    speedup = t_ring / t_paged
    row = {"bench": "paged_prefill_prefix_hit", "requests": N_REQ,
           "shared_tokens": SHARED, "tail_tokens": TAIL,
           "ring_s": round(t_ring, 4), "paged_s": round(t_paged, 4),
           "prefill_speedup": round(speedup, 2),
           "prefix_hit_tokens": int(hit_tokens)}
    print(f"# prefix-hit prefill: ring {t_ring:.3f}s vs paged {t_paged:.3f}s "
          f"-> {speedup:.2f}x (hit {hit_tokens} tokens)")
    print("BENCH " + json.dumps(row), flush=True)
    return row


def bench_decode(model, params, rng, vocab, gen=48):
    """Steady-state decode throughput, 4 concurrent requests."""
    prompts = [rng.integers(0, vocab, (16,)).astype(np.int32)
               for _ in range(4)]

    def tok_per_s(eng):
        _submit_all(eng, prompts, 2)
        eng.run()                    # compile outside the timed region
        before = eng.stats.tokens
        _submit_all(eng, prompts, gen)
        dt = _timed_run(eng)
        return (eng.stats.tokens - before) / dt

    ring_tps = tok_per_s(ServeEngine(model, params, n_slots=4,
                                     cache_len=CACHE_LEN))
    paged_tps = tok_per_s(PagedServeEngine(
        model, params, n_slots=4, cache_len=CACHE_LEN, block_size=BLOCK))
    row = {"bench": "paged_decode_throughput", "batch": 4, "gen": gen,
           "ring_tok_s": round(ring_tps, 1), "paged_tok_s": round(paged_tps, 1),
           "paged_over_ring": round(paged_tps / ring_tps, 3)}
    print(f"# decode throughput: ring {ring_tps:.1f} tok/s vs paged "
          f"{paged_tps:.1f} tok/s ({row['paged_over_ring']:.2f}x; gather + "
          f"read-time block verify is the overhead)")
    print("BENCH " + json.dumps(row), flush=True)
    return row


def run() -> list[dict]:
    # a step up from the -smoke width so compute dominates per-call dispatch
    # overhead (the regime the paged cache targets); still CPU-friendly
    from repro.configs import reduced
    cfg = reduced(get_config("gpt2"), layers=4, d_model=128, vocab=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = [bench_prefill(model, params, rng, cfg.vocab_size),
            bench_decode(model, params, rng, cfg.vocab_size)]
    return rows


def main() -> None:
    argparse.ArgumentParser().parse_args()
    rows = run()
    sp = rows[0]["prefill_speedup"]
    print(f"# prefix-hit prefill speedup: {sp:.2f}x "
          f"({'OK' if sp >= 2.0 else 'BELOW TARGET'})")


if __name__ == "__main__":
    main()
