"""End-to-end serving driver (the paper's use case is inference): the
continuous-batching engine serves mixed-length requests while soft errors
strike its attention layers. EFTA corrects them in-kernel; on detect-only
faults the engine retries the step; sustained fault rates escalate.

  PYTHONPATH=src python examples/serve_fault_tolerant.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import FaultSpec, Site
from repro.models import build_model
from repro.serve import ServeEngine, batch_faults, greedy_generate

cfg = get_config("gpt2-smoke")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
      f"ft={cfg.ft.mode} (EFTA stride {cfg.ft.stride})")

# 8 mixed-length requests over 4 cache slots; an SEU strikes decode step 2
eng = ServeEngine(model, params, n_slots=4, cache_len=48)
for _ in range(8):
    t = int(rng.integers(4, 25))
    eng.submit(rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32),
               max_new_tokens=8)
seu = FaultSpec.single(Site.GEMM1, block=0, batch=0, head=1, row=0, col=3,
                       bit=27)
outs = eng.run({2: batch_faults(4, {1: seu})})
summ = eng.telemetry.summary()
print(f"served {len(outs)} requests / {eng.stats.tokens} tokens in "
      f"{eng.stats.steps} batched steps over 4 slots; EFTA detected="
      f"{summ['detected']} retries={summ['retries']} status={summ['status']}")
for rid in sorted(outs):
    st = eng.telemetry.requests[rid]
    print(f"  request {rid}: {len(outs[rid])} tokens, "
          f"detected={st.total_detected} corrected={st.total_corrected}")

# the batched engine must agree token-for-token with sequential decoding,
# and EFTA-protected decoding with FT disabled (no false corrections)
prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
ref, _ = greedy_generate(model, params, jax.numpy.asarray(prompt[None]),
                         steps=6)
off = build_model(dataclasses.replace(
    cfg, ft=dataclasses.replace(cfg.ft, mode="off")))
ref_off, _ = greedy_generate(off, params, jax.numpy.asarray(prompt[None]),
                             steps=6)
eng2 = ServeEngine(model, params, n_slots=2, cache_len=48)
rid = eng2.submit(prompt, max_new_tokens=6)
got = eng2.run()[rid]
assert (np.asarray(ref)[0] == got).all()
assert (np.asarray(ref) == np.asarray(ref_off)).all()
print("OK: batched continuous decoding is token-identical to the sequential "
      "loop, and EFTA protection is bit-transparent in the fault-free case.")
