"""Benchmark harness utilities.

CPU-host caveat: wall-clock here measures the *relative* overheads the paper
reports (FT time / total time); absolute TPU-scale performance lives in the
roofline analysis (benchmarks/roofline.py over experiments/dryrun)."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call of a jitted fn (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(rows: list[dict], header: str):
    """Print ``name,us_per_call,derived`` CSV rows."""
    print(f"# {header}")
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us', ''):.1f},{r.get('derived', '')}")
    print(flush=True)


def qkv(b, h, hkv, s, d, dtype, seed=0):
    import jax.numpy as jnp
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, s, d), dtype),
            jax.random.normal(ks[1], (b, hkv, s, d), dtype),
            jax.random.normal(ks[2], (b, hkv, s, d), dtype))
