"""Pure-jnp oracles for the Pallas EFTA kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.efta import reference_attention  # noqa: F401  (re-export)


def fold1_ref(x, stride):
    g = x.shape[-1] // stride
    return x.reshape(*x.shape[:-1], g, stride).astype(jnp.float32).sum(-2)


def fold2_ref(x, stride):
    g = x.shape[-1] // stride
    w = jnp.arange(1, g + 1, dtype=jnp.float32)
    xr = x.reshape(*x.shape[:-1], g, stride).astype(jnp.float32)
    return (xr * w[:, None]).sum(-2)


def foldprod_ref(x, stride):
    g = x.shape[-1] // stride
    return x.reshape(*x.shape[:-1], g, stride).astype(jnp.float32).prod(-2)


def attention_ref(q, k, v, *, causal=False, window=None, sm_scale=None):
    """Oracle for the kernel: naive softmax attention (GQA aware)."""
    return reference_attention(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale)
