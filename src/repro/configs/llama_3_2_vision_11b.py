"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers every 5th layer; vision frontend
is a stub providing precomputed patch embeddings (1600 tokens).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, d_ff=14336, vocab_size=128256,
    attn=AttnCfg(num_heads=32, num_kv_heads=8, head_dim=128),
    cross_attn_every=5, frontend_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
