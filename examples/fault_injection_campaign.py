"""Model-level error-injection campaign (paper §5.3 style): random SEUs are
injected into attention of a small transformer during inference; we measure
silent-corruption rates with EFTA off/detect/correct.

  PYTHONPATH=src python examples/fault_injection_campaign.py [n_trials]
"""
import dataclasses
import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EFTAConfig, FaultSpec, Site
from repro.core.efta import efta_attention, reference_attention

N = int(sys.argv[1]) if len(sys.argv) > 1 else 40
B, H, S, D = 1, 4, 128, 32
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, H, S, D))
k = jax.random.normal(ks[1], (B, H, S, D))
v = jax.random.normal(ks[2], (B, H, S, D))
ref = reference_attention(q, k, v)
rng = np.random.default_rng(1)
SITES = [Site.GEMM1, Site.EXP, Site.ROWMAX, Site.ROWSUM, Site.GEMM2]

for mode in ("off", "correct"):
    cfg = EFTAConfig(mode=mode, stride=8, block_kv=32)
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    silent = detected = harmless = 0
    worst = 0.0
    for _ in range(N):
        f = FaultSpec.single(
            SITES[int(rng.integers(0, len(SITES)))],
            block=int(rng.integers(0, S // 32)), batch=0,
            head=int(rng.integers(0, H)), row=int(rng.integers(0, S)),
            col=int(rng.integers(0, S)), bit=int(rng.integers(16, 31)))
        out, rep = fn(q, k, v, fault=f)
        err = float(jnp.max(jnp.abs(out - ref)))
        det = int(np.sum(np.asarray(rep.detected))) > 0
        if err < 1e-3:
            harmless += 1
        elif det:
            detected += 1
        else:
            silent += 1
        worst = max(worst, err)
    print(f"mode={mode:8s} trials={N} harmless={harmless} "
          f"caught={detected} SILENT={silent} worst_residual={worst:.2e}")
print("EFTA turns silent corruptions into detected (and corrected) events.")
