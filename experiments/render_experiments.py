"""Render EXPERIMENTS.md from the dry-run/hillclimb artifacts.

  PYTHONPATH=src python experiments/render_experiments.py > EXPERIMENTS.md
"""
import json
import sys
from pathlib import Path

OUT = Path("experiments/dryrun")

HEADER = """# EXPERIMENTS — FT-Transformer / EFTA on TPU (multi-pod JAX)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Container is CPU-only: wall-clock numbers are *relative* overheads at reduced
shapes (the paper's own metric); TPU-scale performance is derived from the
compiled HLO of the production-mesh dry-run (methodology below).

## Paper-claims validation (faithful reproduction)

| paper claim | our result | artifact |
|---|---|---|
| EFTA corrects single-bit faults in GEMM-I/EXP/rowsum/GEMM-II inside one fused attention | all 5 sites detected+corrected to numerical noise (f32), both pure-JAX and Pallas kernel | tests/test_efta.py, tests/test_kernels_pallas.py |
| Rowmax errors cancel analytically (Case 1) | confirmed in exact arithmetic; REFUTED under masking/fp-overflow corners — shadow-rowmax guard added (beyond paper) | tests/test_efta.py::test_fault_corrected |
| Unified verification (EFTA-o) cuts FT overhead vs per-block | confirmed: per-block output verification costs more at every seq length | benchmarks/bench_tab12_unified_verification.py |
| EFTA beats decoupled ABFT+DMR; decoupled OOMs at 16k | confirmed: speedup at all scaled seq lengths; decoupled S+P footprint 64 GB at 16k (> A100-40GB) | benchmarks/bench_fig09* |
| Tensor-checksum ABFT: wider interleaved checksums raise multi-error coverage | confirmed: errors in distinct fold columns corrected; stride-aliased pairs are the documented limit | tests/test_checksum.py::test_interleaved_multi_error_advantage |
| ~92.5% coverage at high bit-error rates (not 100%) | reproduced: EXP-stage product check is underflow-blind for denormal probabilities; layered NVR clamp (beyond paper) bounds the residual | benchmarks/bench_fig12* |
| Average FT overhead ~13.9% (A100) | on TPU-model FLOP accounting: checksum-width overhead = 2*s_kv/Bc (GEMM-I) + 2*s_out/d (GEMM-II) = 6-12% at tuned widths; wall-clock overhead on CPU host is larger (no MXU) and reported per bench | benchmarks/bench_fig10*, §Perf |

Beyond-paper hardening (all opt-in-able, defaults on; see DESIGN.md §7):
f32 single-rounding checksum encode (paper's fp16 encode forces loose 0.48
thresholds), relative thresholds floored at checksum RMS, shadow rowsum/rowmax
accumulators (exact correction where the paper only approximates), NVR clamp
P<=1.

## §Dry-run — multi-pod certification

`launch/dryrun.py` lowers + compiles every (arch x shape x mesh) cell for the
production meshes 16x16 (256 chips) and 2x16x16 (512 chips, `pod` axis) with
parameter/optimizer/cache ShapeDtypeStructs (no allocation). Compile success
certifies the sharding config (FSDP x TP x EP rules in
distributed/sharding.py); `memory_analysis()` gives per-device bytes.
`long_500k` cells are skipped for pure full-attention archs per the
assignment and run for hymba/rwkv6/gemma3 (sub-quadratic).

Roofline-term methodology: XLA cost_analysis counts while-loop bodies once
(verified), so per-layer costs come from two flag-aware UNROLLED probe
compiles (k1, k2 = k1+period) extrapolated linearly — exact for the layer-
periodic structure of every arch; SSM per-timestep recurrences remain inside
the loop (documented 1-5% undercount on ssm archs). Collective bytes are
result-shape sums over all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute ops in the partitioned HLO.

NOTE on "bytes accessed": XLA charges each fusion's operands+outputs; the
pure-JAX EFTA materializes S/P tiles at fusion boundaries that the Pallas
fused kernel (the paper's artifact, `kernels/efta_attention.py`) keeps in
VMEM — the §Perf "kernelized" iteration quantifies exactly this gap.
"""

PERF = """
## §Perf — hillclimb log (hypothesis -> change -> measure -> verdict)

Selection per the assignment: most collective-bound cell, worst
memory-pressure cell, most paper-representative cell. The paper-faithful
baseline (EFTA defaults, FSDP x TP rules) is recorded first; beyond-paper
optimizations are tagged variants of the same cell.

### Cell A — kimi-k2-1t-a32b x decode_32k x 16x16 (most collective-bound)

1. **Hypothesis**: decode gathers FSDP-sharded weights every step — per
   device per step the MoE all-gathers ~3 GB of expert weights over `data`
   while moving only ~128 tokens; the collective term should be dominated by
   these gathers, and an inference layout (dense weights pure-TP, experts
   fully sharded E-over-data x ff-over-model, tokens all-gathered instead)
   should cut collective bytes by orders of magnitude.
   Napkin: weight-gather bytes/step ~ params_bytes/data_degree x layers-touch
   vs token bytes ~ B x d x 2 = 1.8 MB.
2. **Change**: `param_shardings(inference=True)` + `MoECfg.inference_ep` —
   see distributed/sharding.py and models/moe.py::_moe_inference_ep.
3. **Measured** (tag `infer_layout`): collective term 6.25s -> 1.05s
   (**6.0x**), memory 4.03 -> 3.10s, compute 7.9 -> 5.3ms. The cell flips
   from collective- to memory-dominant (now KV-cache + weight streaming —
   the irreducible decode traffic).
4. **Verdict**: CONFIRMED. Peak bytes stayed ~flat (buffer liveness around
   the cache update, not the gathers) — recorded, next lever would be int8
   KV cache. Stopping: one iteration moved the dominant term 6x; remaining
   levers (<5% each on the new dominant term) fall under the stop rule.

### Cell B — arctic-480b x train_4k x 16x16 (worst memory pressure)

1. **Hypothesis (mb4)**: peak temp is dominated by whole-batch activation
   liveness (layer-scan residuals ~16 GB at B_loc=8, plus f32 optimizer
   temporaries over stacked leaves); 4 microbatches shrink it ~4x at equal
   FLOPs. **Change**: `make_train_step(microbatches=4)`.
   **Measured**: peak 152.3 -> 67.3 GB (**-56%**); compute flat (3.39 vs
   3.40s) but memory bytes +18% and collectives +94% (FSDP weight gathers
   repeat per microbatch — a real, known FSDP-accumulation tax).
   **Verdict**: CONFIRMED for peak (the target), with the quantified
   collective cost; methodology note — the microbatch loop is a while in
   HLO, so probe costs are scaled by the accumulation factor.
2. **Hypothesis (seqpar)**: residual memory and inter-block activation
   traffic scale with full-S activations; Megatron sequence parallelism
   shards them over `model` (16x smaller residuals) for all-gather/
   reduce-scatter pairs at block boundaries. **Change**:
   `ModelConfig.seq_parallel=True` (+mb4). **Measured**: peak 67.3 ->
   52.4 GB (-22%) but collective term 41 -> 62s and rf 0.040 -> 0.031.
   **Verdict**: PARTIALLY REFUTED on this MoE arch — arctic is already
   ICI-heavy from expert gathers, so SP's comm outweighs its memory win
   here (it remains the right lever for dense archs / larger batch).
3. **Hypothesis (s8 vs s128)**: the "lane-aligned s=128 checksum" port of
   the paper's MMA-layout trick is WRONG on TPU at narrow KV blocks:
   checksum *width* sets extra MXU columns (2s/Bc on GEMM-I = +50% at
   s=128/Bc=512), fold *layout* only touches cheap VPU adds. **Change**:
   pin fold widths to 8 vs 128. **Measured**: compute 3.390 -> 3.598s
   (**+6.1%** whole-model; attention is ~12% of arctic's MoE-heavy FLOPs,
   so the attention-local penalty is ~50% as predicted). **Verdict**:
   CONFIRMED (the naive port is refuted; widths stay auto-tuned at 6-12%
   MXU overhead with >= 2x the paper's multi-error spacing).

### Cell C — deepseek-coder-33b x prefill_32k x 16x16 (paper-representative)

1. **Hypothesis (kernelized)**: the XLA-compiled (unfused) EFTA pays HBM
   round-trips for every S/P tile between matmul/exp/mask ops — the exact
   traffic the paper's fused kernel eliminates. Summing the S/P-tile-shaped
   op results in the probe HLO measures that traffic; subtracting it models
   the Pallas-kernel deployment (kernels/efta_attention.py, validated in
   interpret mode) and should move the cell from memory-bound toward
   compute-bound.
2. **Change**: deploy `kernels/efta_attention.py` for the attention layer
   (accounting via HLO tile-byte measurement; the kernel itself is the
   artifact).
3. **Measured** (tag `kernelized`): S/P-tile HBM traffic in the unfused
   HLO = **23.4 TB/device/step**; removing it cuts the memory term
   5.32e+01s -> 2.46e+01s (**2.2x**). Compute term 3.0s.
4. **Verdict**: CONFIRMED and conservative — the accounting subtracts only
   S/P-tile-shaped transfers; the fused kernel also keeps the (B,H,Sq,D)
   output accumulator in VMEM across KV steps (~1 TB more). The cell stays
   memory-bound after fusion: remaining bytes are KV streaming + carry
   traffic, pointing at block_q retuning as the next (sub-5%-per-step)
   lever — stop rule reached.

### Hillclimb result table (tagged artifacts in experiments/dryrun)
"""


def fmt_row(r):
    t = r.get("roofline")
    if t is None:
        return (f"| {r['arch']} | {r['shape']} | {r.get('tag','')} | "
                f"{r['compute_s']:.2e} | {r['memory_s_fused']:.2e} | - | - | "
                f"(memory term after fusing: baseline {r['memory_s_baseline']:.2e}) |")
    rf = r.get("roofline_fraction")
    return (f"| {r['arch']} | {r['shape']} | {r.get('tag','') or 'baseline'} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | {r['memory']['peak_bytes']/1e9:.1f} "
            f"| rf={rf and round(rf, 4)} dom={r['dominant'][:-2]} |")


def main():
    rows = [json.loads(p.read_text()) for p in sorted(OUT.glob("*.json"))]
    base = [r for r in rows if not r.get("tag")]
    tagged = [r for r in rows if r.get("tag")]

    print(HEADER)
    for mesh in ("16x16", "2x16x16"):
        sel = [r for r in base if r["mesh"] == mesh]
        print(f"\n### Dry-run + §Roofline — mesh {mesh} "
              f"({'512' if mesh != '16x16' else '256'} chips)\n")
        print("| arch | shape | kind | compute_s | memory_s | collective_s "
              "| dominant | peak GB | fits 16GB | useful-FLOPs ratio "
              "| roofline fraction |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in sorted(sel, key=lambda r: (r["arch"], r["shape"])):
            t = r["roofline"]
            print(f"| {r['arch']} | {r['shape']} | {r['kind']} "
                  f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
                  f"| {t['collective_s']:.2e} | {r['dominant'][:-2]} "
                  f"| {r['memory']['peak_bytes']/1e9:.1f} "
                  f"| {r['memory']['fits_16gb']} "
                  f"| {r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)} "
                  f"| {r['roofline_fraction'] and round(r['roofline_fraction'],4)} |")

    print("""
Reading the table: *dominant* is the roofline bottleneck per cell; *useful-
FLOPs ratio* = MODEL_FLOPS(6ND / 6N_active*D) / compiled HLO FLOPs (remat
recompute, attention quadratic terms, checksum overhead and head-padding
waste all lower it); *roofline fraction* = ideal model-FLOPs time / dominant
term (the score a perfect overlap schedule could reach). Decode cells are
inherently bandwidth-bound (rf ~ 0 is expected: one token per sequence).
One-line lever per dominant term: compute -> causal block skipping + narrower
checksums + less remat; memory -> Pallas-fused attention (S/P in VMEM),
sequence parallelism, microbatching; collective -> inference weight layouts,
int8 gradient sync, overlap via latency-hiding scheduler.

Per-device HBM notes: cells with fits=False at 16x16 record the finding that
the arch x shape needs the 512-chip mesh (or the §Perf changes): the 2x16x16
column shows the same cell at half the per-device footprint. kimi/arctic
train peaks are dominated by f32 optimizer temporaries + layer-scan
residuals — mb4/seqpar address exactly these (see §Perf).""")

    print(PERF)
    print("| arch | shape | variant | compute_s | memory_s | collective_s "
          "| peak GB | note |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(base, key=lambda r: r["arch"]):
        if (r["arch"], r["shape"], r["mesh"]) in {
            ("kimi-k2-1t-a32b", "decode_32k", "16x16"),
            ("arctic-480b", "train_4k", "16x16"),
            ("deepseek-coder-33b", "prefill_32k", "16x16")}:
            print(fmt_row(r))
    for r in sorted(tagged, key=lambda r: (r["arch"], r.get("tag", ""))):
        print(fmt_row(r))

    print("""
### Perf summary (the score)

Best roofline fractions reached (ideal-model-FLOPs time / dominant term):
train cells peak at **0.079** (starcoder2/deepseek train_4k baseline) under
the pure-JAX attention path; §Perf cell C shows kernel fusion alone doubles
the achievable fraction on attention-heavy cells (memory term 2.2x down),
and cell A shows the decode serving path gains 6x on its dominant
(collective) term from the inference layout. Decode cells sit at rf ~ 0 by
construction (one token per sequence against streamed weights/KV — the
correct lever there is batching, quantized KV, and the measured layout fix,
not FLOPs). The useful-FLOPs ratio column isolates where compiled compute
exceeds 6ND: full-layer remat (+~33% on train), causal masking computed as
full blocks (up to 2x on attention scores), GQA head padding (56->64 = +14%
on arctic/deepseek attention), and the 6-12% checksum width — each a
recorded, bounded engineering trade with its lever noted above.""")


if __name__ == "__main__":
    main()
