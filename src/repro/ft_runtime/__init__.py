from repro.ft_runtime.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore, save)
from repro.ft_runtime.elastic import MeshPlan, build_mesh, plan_mesh
from repro.ft_runtime.monitor import (FaultRateMonitor, RequestFaultStats,
                                      ServeFaultTelemetry, StragglerMonitor)
