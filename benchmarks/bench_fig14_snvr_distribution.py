"""Paper Fig. 14: residual-error distribution after restriction-based
correction: SNVR (paper analytic fallback) vs shadow accumulator (ours) vs
no protection, under ROWSUM faults."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, qkv
from repro.core import EFTAConfig, FaultSpec, Site
from repro.core.efta import efta_attention, reference_attention

B, H, S, D = 1, 2, 128, 32
TRIALS = 40


def residuals(cfg, seed=0):
    q, k, v = qkv(B, H, H, S, D, jnp.float32, seed=seed)
    ref = reference_attention(q, k, v)
    fn = jax.jit(functools.partial(efta_attention, cfg=cfg))
    rng = np.random.default_rng(seed)
    errs = []
    for _ in range(TRIALS):
        f = FaultSpec.single(Site.ROWSUM,
                             block=int(rng.integers(0, S // cfg.block_kv)),
                             batch=0, head=int(rng.integers(0, H)),
                             row=int(rng.integers(0, S)), col=0,
                             bit=int(rng.integers(20, 31)))
        out, _ = fn(q, k, v, fault=f)
        errs.append(float(jnp.max(jnp.abs(out - ref))))
    return np.asarray(errs)


def pct(e):
    return (f"p50={np.percentile(e,50):.2e};p90={np.percentile(e,90):.2e}"
            f";max={e.max():.2e}")


def run():
    rows = []
    for name, cfg in [
        ("no_protection", EFTAConfig(mode="off", block_kv=32)),
        ("snvr_paper_approx", EFTAConfig(mode="correct", stride=8,
                                         block_kv=32, shadow_rowsum=False)),
        ("snvr_shadow_ours", EFTAConfig(mode="correct", stride=8,
                                        block_kv=32)),
    ]:
        e = residuals(cfg)
        rows.append({"name": name, "us": 0.0, "derived": pct(e)})
    emit(rows, "Fig14: residual error distribution under ROWSUM faults")
    return rows


if __name__ == "__main__":
    run()
