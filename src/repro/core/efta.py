"""End-to-End Fault Tolerant Attention (EFTA) — paper Algorithm 1 in pure JAX.

This is the framework-level implementation that models call: a flash-attention
style `lax.scan` over KV blocks with the paper's hybrid fault-tolerance scheme
fused into the same computation:

  * GEMM I  (S = Q·Kᵀ)      — tensor-checksum ABFT (encode K checksums, verify
                              the strided-fold identity on S, locate + correct)
  * subtract-max + EXP       — checksum reuse: the *same* S checksum, shifted
                              by ``g·m``, must equal the strided fold of
                              ``log P`` (the paper's product identity, Alg.1
                              line 13, verified in the log domain so
                              underflowing columns stay covered); EXP faults
                              are corrected by recomputation
  * ROWMAX                   — unprotected by design: errors cancel analytically
                              (paper Case 1); we compute in f32 to avoid the
                              overflow corner
  * ROWSUM (ℓ)               — SNVR: range restriction ``Σ_k e^{m_k - m} ≤ ℓ ≤
                              kv_len`` with analytic-approximation correction
                              (paper Case 3 / Alg.1 lines 22-24)
  * GEMM II + rescale + norm — unified verification: one output checksum is
                              carried through every rescale and the final
                              normalization, verified **once** at the end
                              (paper Alg.1 lines 18-28)

The TPU-native Pallas kernel (`repro.kernels.efta_attention`) implements the
same algorithm with explicit VMEM tiling; this module is its jit/pjit-friendly,
differentiable twin and the one exercised by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import checksum as cks
from repro.core.fault import FaultSpec, Site, inject

MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


# optimization_barrier defeats CSE so the shadow (DMR) accumulators are
# genuinely redundant computations on real hardware — but this jax version
# ships the primitive without batching or differentiation rules, which breaks
# vmap (the serve engine's batched decode) and jax.grad (training). Both
# rules are mathematically trivial: the barrier is the identity function, so
# batching keeps the batch axis and the JVP passes tangents through.
from jax.interpreters import batching as _batching  # noqa: E402

if jax.lax.optimization_barrier_p not in _batching.primitive_batchers:
    def _ob_batch(args, dims):
        return jax.lax.optimization_barrier_p.bind(*args), dims
    _batching.primitive_batchers[jax.lax.optimization_barrier_p] = _ob_batch


@jax.custom_jvp
def _shadow_barrier(x):
    return jax.lax.optimization_barrier(x)


@_shadow_barrier.defjvp
def _shadow_barrier_jvp(primals, tangents):
    return _shadow_barrier(primals[0]), tangents[0]


@dataclasses.dataclass(frozen=True)
class EFTAConfig:
    """Fault-tolerance + tiling configuration for EFTA."""

    mode: str = "correct"          # "off" | "detect" | "correct"
    stride: int = cks.TPU_STRIDE   # max checksum fold stride (8 = paper)
    block_kv: int = 512            # KV block size (Bc)
    unified: bool = True           # unified verification (EFTA-o) vs per-block
    unroll: bool = False           # unroll the KV-block scan (dry-run probes)
    # Checksum *width* drives the MXU overhead: the checksum GEMMs add
    # 2*s_kv/Bc (GEMM I) and 2*s_out/d (GEMM II) extra FLOPs. The fold
    # *layout* (lane-aligned vs strided) is a VPU concern only. So widths
    # auto-tune to keep MXU overhead ~6-12% unless explicitly pinned —
    # measured in EXPERIMENTS.md §Perf (hypothesis: the naive s=128 "lane
    # aligned" port costs +50% GEMM-I FLOPs at Bc=512 — confirmed, refused).
    kv_stride_override: Optional[int] = None
    out_stride_override: Optional[int] = None
    # Beyond-paper: exact rowsum correction via a shadow accumulator (one f32
    # row vector in VMEM — cheap on TPU, where the paper avoided DMR because
    # of GPU register pressure). False = paper-faithful analytic approximation.
    shadow_rowsum: bool = True
    # Beyond-paper: recompute-compare on the running rowmax (one (Br,1) max +
    # compare) and NVR range-clamp P <= 1. The paper relies on analytic
    # cancellation of rowmax errors (Case 1), which holds only in exact
    # arithmetic — an understated max overflows exp() in fp16/bf16 on real
    # hardware. False = paper-faithful behaviour.
    shadow_rowmax: bool = True
    # Detection thresholds (see DESIGN.md §7.2 — re-derived for bf16).
    eps_gemm1: Optional[float] = None
    eps_exp: Optional[float] = None
    eps_out: Optional[float] = None

    def thresholds(self, dtype) -> tuple[float, float, float]:
        # All thresholds are RELATIVE to checksum magnitude (the paper's
        # absolute 0.48 for fp16 corresponds to ~0.05 relative at their
        # |S|~10 score scale). bf16 encode/verify rounding is ~2^-8 relative,
        # leaving a ~12x detection margin at 0.05.
        if jnp.dtype(dtype) == jnp.float32:
            d = (1e-3, 1e-3, 1e-3)
        else:  # bf16 / fp16 mixed precision — coarse mantissa
            # eps_exp stays loose: bf16 K-checksum rounding is an *absolute*
            # ~2^-8 * g * |s| error in the log-domain fold, which does not
            # shrink when the fold value itself cancels toward zero.
            d = (5e-2, 1.0, 5e-2)
        return (
            self.eps_gemm1 if self.eps_gemm1 is not None else d[0],
            self.eps_exp if self.eps_exp is not None else d[1],
            self.eps_out if self.eps_out is not None else d[2],
        )

    def out_stride(self, head_dim: int) -> int:
        # Keep >= 2 fold segments so the output checksum is a real fold, not a
        # duplicate (g=1 would degenerate tensor-checksum ABFT into DMR).
        if self.out_stride_override:
            s = min(self.out_stride_override, head_dim // 2)
        else:
            s = max(min(self.stride, head_dim // 16, 64), 4)
        while s > 1 and head_dim % s:
            s -= 1
        return max(s, 1)

    def kv_stride(self, block_kv: int) -> int:
        if self.kv_stride_override:
            return min(self.kv_stride_override, max(block_kv // 2, 1))
        p = max(block_kv // 32, 1)
        pow2 = 1 << (p.bit_length() - 1)
        return max(min(self.stride, pow2), 4)


class FTReport(NamedTuple):
    """Aggregatable fault-tolerance telemetry for one attention call."""

    detected: jax.Array    # (5,) int32 — [gemm1, exp, rowmax, rowsum, gemm2]
    corrected: jax.Array   # (5,) int32
    max_delta: jax.Array   # (3,) f32  — [gemm1 linear, exp product, out]

    @staticmethod
    def zero() -> "FTReport":
        return FTReport(
            jnp.zeros((5,), jnp.int32),
            jnp.zeros((5,), jnp.int32),
            jnp.zeros((3,), jnp.float32),
        )

    def merge(self, other: "FTReport") -> "FTReport":
        return FTReport(
            self.detected + other.detected,
            self.corrected + other.corrected,
            jnp.maximum(self.max_delta, other.max_delta),
        )


def _pad_kv(x: jax.Array, block: int) -> jax.Array:
    skv = x.shape[-2]
    pad = (-skv) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
    return x


def reference_attention(q, k, v, *, causal=False, window=None, kv_len=None,
                        q_offset=0, sm_scale=None, kv_positions=None):
    """Naive softmax attention oracle (O(n^2) memory). GQA-aware."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bkgqd,bkgcd->bkgqc" if k.ndim == 5 else "bkgqd,bkcd->bkgqc",
                   qf, k.astype(jnp.float32)) * scale
    s = s.reshape(b, h, sq, k.shape[-2])
    mask = _full_mask(sq, k.shape[-2], causal=causal, window=window,
                      kv_len=kv_len, q_offset=q_offset,
                      kv_positions=kv_positions)
    s = jnp.where(mask, s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    pr = p.reshape(b, hkv, g, sq, k.shape[-2])
    o = jnp.einsum("bkgqc,bkcd->bkgqd", pr, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def _full_mask(sq, skv, *, causal, window, kv_len, q_offset, kv_positions=None):
    qpos = jnp.arange(sq)[:, None] + q_offset
    if kv_positions is not None:
        kpos = kv_positions[None, :]
        m = kpos >= 0
    else:
        kpos = jnp.arange(skv)[None, :]
        m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= qpos - kpos < window
    if kv_len is not None and kv_positions is None:
        m &= kpos < kv_len
    return m


def efta_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    cfg: EFTAConfig,
    causal: bool = False,
    window: Optional[int] = None,
    kv_len: Optional[jax.Array] = None,
    q_offset=0,
    sm_scale: Optional[float] = None,
    fault: Optional[FaultSpec] = None,
    kv_positions: Optional[jax.Array] = None,
) -> tuple[jax.Array, FTReport]:
    """EFTA forward. q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D), H % Hkv == 0.

    Returns (output (B, H, Sq, D) in q.dtype, FTReport).
    ``kv_len`` masks a ragged KV cache; ``q_offset`` aligns causal masks when
    q is a suffix of the sequence (decode: q_offset = kv_len - Sq).
    ``kv_positions`` (Skv,) gives the absolute position held in each KV slot
    (ring caches); -1 marks invalid slots. Supersedes ``kv_len``.
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    grp = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    ft = cfg.mode != "off"
    correct = cfg.mode == "correct"
    eps1, eps2, eps3 = cfg.thresholds(q.dtype)

    block = min(cfg.block_kv, max(skv, 1))
    # round the block to a multiple of the fold stride (odd cache lengths
    # from serving are padded + masked below)
    for _ in range(2):
        s_fix = cfg.kv_stride(block)
        block = -(-block // s_fix) * s_fix
    k = _pad_kv(k, block)
    v = _pad_kv(v, block)
    skv_p = k.shape[2]
    nblk = skv_p // block
    if kv_positions is not None and skv_p != skv:
        kv_positions = jnp.pad(kv_positions, (0, skv_p - skv),
                               constant_values=-1)
    if kv_len is None and skv_p != skv and kv_positions is None:
        kv_len = jnp.int32(skv)
    s_kv = cfg.kv_stride(block)      # fold stride along the key axis
    s_out = cfg.out_stride(d)        # fold stride along the feature axis
    g_kv = block // s_kv

    # (nblk, B, Hkv, Bc, D) scan layout.
    kb = k.reshape(b, hkv, nblk, block, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblk, block, d).transpose(2, 0, 1, 3, 4)
    qf = q.reshape(b, hkv, grp, sq, d)

    qpos = jnp.arange(sq, dtype=jnp.int32)[:, None] + jnp.asarray(q_offset, jnp.int32)

    def block_mask(blk_idx, kvp_blk=None):
        if kvp_blk is not None:
            kpos = kvp_blk[None, :]
            m = kpos >= 0
        else:
            kpos = blk_idx * block + jnp.arange(block, dtype=jnp.int32)[None, :]
            m = jnp.ones((sq, block), dtype=bool)
        if causal:
            m = m & (kpos <= qpos)
        if window is not None:
            m = m & (qpos - kpos < window)
        if kv_len is not None and kvp_blk is None:
            m = m & (kpos < jnp.asarray(kv_len, jnp.int32))
        return m  # (Sq, Bc)

    kvp_blocks = (kv_positions.reshape(nblk, block)
                  if kv_positions is not None else None)

    def body(carry, inp):
        if kvp_blocks is not None:
            blk_idx, k_j, v_j, kvp_blk = inp
        else:
            blk_idx, k_j, v_j = inp
            kvp_blk = None
        (m_prev, l_prev, lsh_prev, r_prev, o_prev, oc1, oc2, rep) = carry

        # --- CCG: encode checksums of this K/V block (paper Alg.1 line 8) ---
        if ft:
            kc = cks.encode_kv(k_j, s_kv)          # (B,Hkv,s_kv,D) x2
            vc = cks.encode_cols(v_j, s_out)       # (B,Hkv,Bc,s_out) x2

        # --- GEMM I: S = Q Kᵀ (f32 accumulate on the MXU) ------------------
        s_ij = jnp.einsum("bkgqd,bkcd->bkgqc", qf, k_j,
                          preferred_element_type=jnp.float32) * scale
        s_ij = s_ij.reshape(b, h, sq, block)
        s_ij = inject(s_ij, fault, Site.GEMM1, blk_idx)
        if ft:
            # NVR range restriction on scores: attention scores are bounded
            # (|s| <= |q||k|/sqrt(d)); clipping an exponent-bit corruption
            # keeps the weighted fold finite so the ABFT location ratio stays
            # exact; NaN/inf corruptions zero out and the checksum delta then
            # restores the true value exactly.
            s_ij = jnp.where(jnp.isfinite(s_ij),
                             jnp.clip(s_ij, -1e6, 1e6), 0.0)

        if ft:
            sc1 = jnp.einsum("bkgqd,bksd->bkgqs", qf, kc.c1,
                             preferred_element_type=jnp.float32) * scale
            sc2 = jnp.einsum("bkgqd,bksd->bkgqs", qf, kc.c2,
                             preferred_element_type=jnp.float32) * scale
            sc1 = sc1.reshape(b, h, sq, s_kv)
            sc2 = sc2.reshape(b, h, sq, s_kv)
            # Linear verification + correction of S (tensor-checksum ABFT).
            verdict = cks.verify_and_correct(
                s_ij, cks.Checksums(sc1, sc2), s_kv,
                threshold=eps1, correct=correct)
            s_ij = verdict.corrected
            det = rep.detected.at[0].add(verdict.n_detected)
            cor = rep.corrected.at[0].add(verdict.n_detected if correct else 0)
            mxd = rep.max_delta.at[0].max(verdict.max_delta)
            rep = FTReport(det, cor, mxd)

        # --- mask + running max (ROWMAX: paper Case 1, analytic cancel) ----
        bm = block_mask(blk_idx, kvp_blk)
        s_m = jnp.where(bm, s_ij, MASK_VALUE)
        blockmax = jnp.max(s_m, axis=-1)                       # (B,H,Sq)
        m_new = jnp.maximum(m_prev, blockmax)
        m_new = inject(m_new, fault, Site.ROWMAX, blk_idx)
        if ft and cfg.shadow_rowmax:
            # Recompute-compare on the (cheap) rowmax recurrence: protects
            # against fp overflow from an understated max, which the paper's
            # analytic-cancellation argument (Case 1) does not cover.
            m_chk = jnp.maximum(_shadow_barrier(m_prev), blockmax)
            bad_m = m_new != m_chk
            rep = FTReport(
                rep.detected.at[2].add(bad_m.sum(dtype=jnp.int32)),
                rep.corrected.at[2].add(
                    bad_m.sum(dtype=jnp.int32) if correct else 0),
                rep.max_delta)
            if correct:
                m_new = jnp.where(bad_m, m_chk, m_new)
        alive = m_new > MASK_VALUE / 2

        # --- EXP with checksum reuse (paper Case 2 / Alg.1 lines 11-16) ----
        m_sub = jnp.where(alive, m_new, 0.0)
        # Cap keeps the fold-product finite for masked raw entries; unmasked
        # entries satisfy S <= m so the cap never binds on data that matters.
        cap = 80.0 / g_kv
        p_raw = jnp.exp(jnp.minimum(s_ij - m_sub[..., None], cap))
        p_raw = inject(p_raw, fault, Site.EXP, blk_idx)
        if ft:
            # Log-domain fold check (ROADMAP EXP-coverage closure): comparing
            # the strided *product* of P against exp(S_check1 - g*m) goes
            # blind whenever one segment underflows — prod ~ 0 == check ~ 0
            # hides a corruption of any *other* entry in that column. In the
            # log domain the product is a sum, exact down to the f32 normal-
            # range floor, so detect mode no longer loses those columns.
            lc1 = jnp.minimum(sc1 - g_kv * m_sub[..., None], cap * g_kv)
            bad_exp, _ = cks.verify_product_log(p_raw, lc1, s_kv,
                                                threshold=eps2)
            # Exclusions, both computed from the (GEMM1-verified) scores: the
            # cap breaks the identity for columns whose *masked* raw scores
            # exceed it, and entries below the exp-underflow floor have no
            # log-domain image in P. Excluded entries are either zeroed by
            # the mask or exactly-zero probabilities — no coverage loss.
            sm_shift = s_ij - m_sub[..., None]
            excl = (sm_shift > (cap - 1e-3)) | (sm_shift < cks.LOG_PROD_FLOOR)
            col_ok = ~jnp.any(
                excl.reshape(*excl.shape[:-1], g_kv, s_kv), axis=-2)
            bad_exp = bad_exp & col_ok
            n_exp = bad_exp.sum(dtype=jnp.int32)
            if correct:
                # "Recompute" EXP over every segment of a flagged fold column.
                recompute = jnp.exp(jnp.minimum(s_ij - m_sub[..., None], cap))
                expand = bad_exp[..., None, :] & jnp.ones(
                    (g_kv, s_kv), dtype=bool)
                expand = expand.reshape(*bad_exp.shape[:-1], block)
                p_raw = jnp.where(expand, recompute, p_raw)
            delta_exp = jnp.float32(0)
            rep = FTReport(
                rep.detected.at[1].add(n_exp),
                rep.corrected.at[1].add(n_exp if correct else 0),
                rep.max_delta.at[1].max(delta_exp),
            )
        if ft and cfg.shadow_rowmax and correct:
            # Exact recompute backstop (beyond-paper): EXP corruptions whose
            # fold product underflows (g_kv segments of e^{s-m} reach 0 in
            # f32) slip past the product check; recomputing e^{s-m} and
            # compare-and-selecting restores them exactly. The correction
            # path above already materializes this recompute, so the backstop
            # adds one compare+select. Safe only with shadow_rowmax (m is
            # exact); subsumes the previous NVR clamp P <= 1.
            recheck = jnp.exp(jnp.minimum(s_ij - m_sub[..., None], cap))
            slipped = p_raw != recheck
            n_slip = slipped.sum(dtype=jnp.int32)
            p_raw = jnp.where(slipped, recheck, p_raw)
            rep = FTReport(rep.detected.at[1].add(n_slip),
                           rep.corrected.at[1].add(n_slip),
                           rep.max_delta)
        p = jnp.where(bm, p_raw, 0.0)

        # --- rescale + ROWSUM (SNVR tracker r: Σ_k e^{m_k - m}) ------------
        alpha = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
        row = jnp.sum(p, axis=-1)
        l_new = alpha * l_prev + row
        l_new = inject(l_new, fault, Site.ROWSUM, blk_idx)
        if ft and cfg.shadow_rowsum:
            # Redundant accumulation (barrier defeats CSE on real hardware).
            row_sh = jnp.sum(_shadow_barrier(p), axis=-1)
            lsh_new = alpha * lsh_prev + row_sh
        else:
            lsh_new = lsh_prev
        blk_alive = blockmax > MASK_VALUE / 2
        r_new = alpha * r_prev + jnp.where(
            blk_alive, jnp.exp(blockmax - m_sub), 0.0)

        # --- GEMM II + rescale, checksums carried along (Alg.1 l.18-21) ----
        pr = p.astype(q.dtype).reshape(b, hkv, grp, sq, block)
        o_blk = jnp.einsum("bkgqc,bkcd->bkgqd", pr, v_j,
                           preferred_element_type=jnp.float32)
        o_new = alpha[..., None] * o_prev + o_blk.reshape(b, h, sq, d)
        o_new = inject(o_new, fault, Site.GEMM2, blk_idx)
        if ft:
            oc1_blk = jnp.einsum("bkgqc,bkcs->bkgqs", pr, vc.c1,
                                 preferred_element_type=jnp.float32)
            oc2_blk = jnp.einsum("bkgqc,bkcs->bkgqs", pr, vc.c2,
                                 preferred_element_type=jnp.float32)
            oc1 = alpha[..., None] * oc1 + oc1_blk.reshape(b, h, sq, s_out)
            oc2 = alpha[..., None] * oc2 + oc2_blk.reshape(b, h, sq, s_out)
            if not cfg.unified:
                # Unoptimized EFTA (paper Tables 1-2 baseline): verify the
                # output checksum at EVERY kv step instead of once at the end.
                d1o = oc1 - cks.fold1(o_new, s_out)
                bad_o = jnp.abs(d1o) > eps3 * jnp.maximum(
                    jnp.abs(oc1), 1.0)
                rep = FTReport(
                    rep.detected.at[4].add(bad_o.sum(dtype=jnp.int32)),
                    rep.corrected,
                    rep.max_delta)

        return (m_new, l_new, lsh_new, r_new, o_new, oc1, oc2, rep), None

    init = (
        jnp.full((b, h, sq), MASK_VALUE, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, d), jnp.float32),
        jnp.zeros((b, h, sq, s_out), jnp.float32),
        jnp.zeros((b, h, sq, s_out), jnp.float32),
        FTReport.zero(),
    )
    if kvp_blocks is not None:
        xs = (jnp.arange(nblk, dtype=jnp.int32), kb, vb, kvp_blocks)
    else:
        xs = (jnp.arange(nblk, dtype=jnp.int32), kb, vb)
    (m_f, l_f, lsh_f, r_f, o_f, oc1, oc2, rep), _ = jax.lax.scan(
        body, init, xs, unroll=True if cfg.unroll else 1)

    # --- SNVR range restriction on the final rowsum (Alg.1 lines 22-24) ----
    if ft:
        n_keys = kv_len if kv_len is not None else skv
        upper = jnp.asarray(n_keys, jnp.float32) + 1e-3
        in_range = (l_f >= r_f - 1e-3) & (l_f <= upper) & jnp.isfinite(l_f)
        if cfg.shadow_rowsum:
            rel = jnp.maximum(jnp.abs(lsh_f), 1e-6)
            mismatch = jnp.abs(l_f - lsh_f) > 1e-5 * rel
            bad_l = ((~in_range) | mismatch) & (r_f > 0)
            fallback = jnp.where(
                (lsh_f >= r_f - 1e-3) & (lsh_f <= upper) & jnp.isfinite(lsh_f),
                lsh_f, r_f)
        else:
            bad_l = (~in_range) & (r_f > 0)
            fallback = r_f  # paper-faithful analytic approximation
        n_rowsum = bad_l.sum(dtype=jnp.int32)
        if correct:
            l_f = jnp.where(bad_l, fallback, l_f)
        rep = FTReport(
            rep.detected.at[3].add(n_rowsum),
            rep.corrected.at[3].add(n_rowsum if correct else 0),
            rep.max_delta,
        )

    # --- normalization, applied to output and its checksums alike ----------
    l_safe = jnp.where(l_f == 0, 1.0, l_f)[..., None]
    o_norm = o_f / l_safe

    # --- unified verification of GEMM II + rescale + normalization ---------
    if ft:
        if correct:
            # NVR range restriction on the normalized output: O/l is a
            # convex combination of V rows, so |o_norm| <= max|V|. Zeroing
            # violations (incl. NaN/inf from exponent-bit accumulator
            # corruptions) makes the output-checksum delta equal the *true*
            # value, so the unified correction below restores it exactly —
            # without this, a 1e38-magnitude corruption is "corrected" by
            # adding a delta that catastrophically cancels (residual = the
            # whole true value). Same trick as the GEMM1 score clip.
            vbound = jnp.max(jnp.abs(v.astype(jnp.float32))) * 1.001 + 1e-6
            o_norm = jnp.where(
                jnp.isfinite(o_norm) & (jnp.abs(o_norm) <= vbound),
                o_norm, 0.0)
        oc1_n = oc1 / l_safe
        oc2_n = oc2 / l_safe
        verdict = cks.verify_and_correct(
            o_norm, cks.Checksums(oc1_n, oc2_n), s_out,
            threshold=eps3, correct=correct)
        o_norm = verdict.corrected
        rep = FTReport(
            rep.detected.at[4].add(verdict.n_detected),
            rep.corrected.at[4].add(verdict.n_detected if correct else 0),
            rep.max_delta.at[2].max(verdict.max_delta),
        )

    return o_norm.astype(q.dtype), rep


def efta_mha(q, k, v, *, cfg: EFTAConfig, **kw):
    """Convenience wrapper returning only the output (report discarded)."""
    return efta_attention(q, k, v, cfg=cfg, **kw)[0]
