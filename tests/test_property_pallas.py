"""Property-based sweep: the Pallas EFTA kernel must equal the jnp oracle for
arbitrary valid (shape, block, stride) combinations, and any high-bit GEMM
fault must be corrected (hypothesis-generated coordinates)."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import EFTAConfig
from repro.kernels import efta_attention_pallas
from repro.kernels.ref import attention_ref


@given(
    st.sampled_from([(1, 2, 1), (1, 4, 2), (2, 2, 2)]),   # (B, H, Hkv)
    st.sampled_from([(128, 64), (256, 64), (256, 128)]),  # (S, block)
    st.sampled_from([32, 64]),                            # head dim
    st.sampled_from([8, 16]),                             # stride
    st.booleans(),                                        # causal
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_kernel_matches_oracle_under_sweep(bhk, sb, d, stride, causal, seed):
    (b, h, hkv), (s, blk) = bhk, sb
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    cfg = EFTAConfig(mode="correct", stride=stride, block_kv=blk)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, causal=causal,
                                     block_q=min(128, s))
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=3e-6)
    assert int(det.sum()) == 0


@given(st.integers(0, 2**31 - 1), st.integers(23, 30))
@settings(max_examples=10, deadline=None)
def test_kernel_corrects_random_gemm_faults(seed, bit):
    rng = np.random.default_rng(seed)
    b, h, s, d = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    cfg = EFTAConfig(mode="correct", stride=8, block_kv=128)
    fault = jnp.array([0, int(rng.integers(0, 2)), int(rng.integers(0, 2)),
                       int(rng.integers(0, s)), int(rng.integers(0, 128)),
                       bit, 1, 0], jnp.int32)
    out, det = efta_attention_pallas(q, k, v, cfg=cfg, fault=fault,
                                     block_q=128)
    ref = attention_ref(q, k, v)
    # corrected to numerical noise OR the flip was below the detection
    # threshold, in which case the residual is bounded by the threshold
    # itself: |dS| <= eps1 * |checksum| ~ 1e-3 * |fold of ~30-magnitude
    # scores| propagated through softmax => |dOut| <~ 1e-2.
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-2, (err, int(det.sum()))
