"""Paged-decode attention backends: fused block-table kernel vs gather.

Compares steady-state decode throughput of the three
:class:`repro.serve.PagedServeEngine` configurations

  * ``gather/always``  — contiguous gather outside the kernel, full-table
                         read-time checksum verify (the PR-2 baseline whose
                         decode ran ~0.85x of the ring engine)
  * ``gather/stamped`` — generation-stamped verification: only blocks
                         written since their last verified read are folded
                         (steady-state: the tail block per slot)
  * ``fused``          — the block-table EFTA Pallas kernel: no contiguous
                         materialization, batch in the grid, verify fused
                         into the KV streaming loop

plus a modeled per-step HBM traffic account. Off-TPU the fused kernel runs
in *interpret mode*, so its CPU wall-clock measures the interpreter, not the
kernel — the traffic model is the hardware-relevant comparison there (the
gather path moves every KV byte ~3x per step: pool read, contiguous write,
attention read; the fused path streams each block once). On TPU
(``interpret=False``) the wall-clock and the model should agree.

``--prefill`` switches to a prefill-heavy workload (long prompts, short
generations) that exercises the unified multi-token step: the fused backend
prefills through mixed chunked batches of ONE compiled program, the gather
backend through its fixed-width extend chunks — against the one-program-per-
prompt-bucket scheme this replaced. Reports time-to-drain throughput and
the per-backend compiled-program count.

  PYTHONPATH=src python -m benchmarks.bench_paged_attention
  PYTHONPATH=src python -m benchmarks.bench_paged_attention --smoke
  PYTHONPATH=src python -m benchmarks.bench_paged_attention --prefill --smoke

``--smoke`` runs a tiny configuration and asserts all backends are
token-identical (and, under ``--prefill``, that the unified engine compiled
at most two step programs) — the CI guard that fails fast on
kernel-dispatch or chunked-prefill breakage.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _engine(model, params, *, n_slots, cache_len, block_size, **kw):
    from repro.serve import PagedServeEngine
    return PagedServeEngine(model, params, n_slots=n_slots,
                            cache_len=cache_len, block_size=block_size, **kw)


def _drive(eng, prompts, gen):
    """Submit + drain; returns (wall_seconds, rid -> token list)."""
    for p in prompts:
        eng.submit(p, max_new_tokens=gen)
    t0 = time.perf_counter()
    outs = eng.run()
    return time.perf_counter() - t0, outs


def _traffic_model(cfg, *, n_blocks_live, n_slots_live, block_size,
                   check_stride):
    """Per-decode-step HBM bytes touched for the live KV working set."""
    a = cfg.attn
    itemsize = np.dtype(cfg.dtype).itemsize
    kv = 2 * cfg.num_layers * n_blocks_live * a.num_kv_heads * block_size \
        * a.head_dim * itemsize
    cks = 4 * cfg.num_layers * n_blocks_live * a.num_kv_heads * check_stride \
        * a.head_dim * itemsize
    return {
        # pool read + contiguous write + attention read, + checksum read
        "gather/always": 3 * kv + cks,
        # verify folds collapse to ~one tail block per live slot; KV still
        # moves 3x
        "gather/stamped": 3 * kv + cks * n_slots_live / max(n_blocks_live, 1),
        # each block streamed once, checksums ride the same loop
        "fused": kv + cks,
    }


def _compiled_programs(eng) -> int:
    """Compiled step-program count of an engine's hot path (best effort)."""
    fn = getattr(eng, "_step_fused", None) if eng.kernel == "fused" \
        else getattr(eng, "_extend", None)
    try:
        return int(fn._cache_size())
    except (AttributeError, TypeError):
        return -1


def run_prefill(smoke: bool = False) -> None:
    """Prefill-heavy comparison: unified chunked step vs gather chunks."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    n_slots, cache_len, bs, chunk = (2, 64, 16, 16) if smoke \
        else (4, 128, 16, 32)
    n_req, gen = (3, 2) if smoke else (8, 2)
    # long ragged prompts spanning several chunks AND straddling block
    # edges; the warmup round uses *different* prompts of the same lengths
    # so its jit compiles carry over but its prefix-cache entries cannot —
    # the timed round must actually prefill, not replay cache hits
    lengths = [int(rng.integers(cache_len // 2, cache_len - gen))
               for _ in range(n_req)]
    warm_prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
                    for t in lengths]
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in lengths]

    backends = {
        "gather/chunked": dict(),
        "fused/unified": dict(kernel="fused"),
    }
    results, token_streams, engines = {}, {}, {}
    for name, kw in backends.items():
        eng = _engine(model, params, n_slots=n_slots, cache_len=cache_len,
                      block_size=bs, chunk_size=chunk, **kw)
        _drive(eng, warm_prompts, gen)     # warmup: compiles
        dt, outs = _drive(eng, prompts, gen)
        prompt_tokens = sum(len(p) for p in prompts)
        results[name] = (prompt_tokens / dt, eng.paged_stats)
        token_streams[name] = [list(outs[r]) for r in sorted(outs)]
        engines[name] = eng

    ref = token_streams["gather/chunked"]
    for name, got in token_streams.items():
        assert got == ref, f"{name} diverged from gather/chunked: " \
                           f"{got} != {ref}"
    fused_programs = _compiled_programs(engines["fused/unified"])
    print(f"chunked prefill ({'smoke' if smoke else 'full'}; {n_req} ragged "
          f"prompts x {gen} gen tokens, chunk={chunk}, bs={bs}):")
    for name, (tps, st) in results.items():
        print(f"  {name:15s} {tps:9.1f} prompt tok/s   "
              f"mixed-batch prefill tokens={st.chunked_prefill_tokens}")
    print(f"  fused unified-step programs compiled: {fused_programs} "
          f"(<= 2: chunk width + decode width; was one per prompt bucket)")
    if smoke:
        assert fused_programs in (-1, 1, 2), \
            f"unified engine compiled {fused_programs} step programs"
        assert engines["fused/unified"].paged_stats.chunked_prefill_tokens > 0
        print("SMOKE OK: chunked prefill token-identical across backends")


def run(smoke: bool = False) -> None:
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    n_slots, cache_len, bs = (2, 32, 16) if smoke else (4, 64, 16)
    n_req, gen = (2, 4) if smoke else (6, 16)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (int(rng.integers(4, 14)),)).astype(np.int32)
               for _ in range(n_req)]

    backends = {
        "gather/always": dict(),
        "gather/stamped": dict(kv_verify="stamped"),
        "fused": dict(kernel="fused"),
    }
    results, token_streams = {}, {}
    for name, kw in backends.items():
        eng = _engine(model, params, n_slots=n_slots, cache_len=cache_len,
                      block_size=bs, **kw)
        _drive(eng, prompts, gen)          # warmup: compiles + admissions
        dt, outs = _drive(eng, prompts, gen)
        tokens = sum(len(v) for v in outs.values())
        results[name] = (tokens / dt, eng.paged_stats)
        token_streams[name] = {r: list(outs[r]) for r in outs}

    # dispatch-parity guard: every backend must emit identical tokens for
    # identical request streams (rids differ across engines; compare by
    # submission order within each engine's second batch)
    ref_name = "gather/always"
    ref = [token_streams[ref_name][r]
           for r in sorted(token_streams[ref_name])]
    for name in backends:
        got = [token_streams[name][r] for r in sorted(token_streams[name])]
        assert got == ref, f"{name} diverged from {ref_name}: {got} != {ref}"

    n_live = sum(-(-len(p) // bs) for p in prompts) + n_req
    model_bytes = _traffic_model(cfg, n_blocks_live=n_live,
                                 n_slots_live=min(n_slots, n_req),
                                 block_size=bs, check_stride=8)
    print(f"paged decode backends ({'smoke' if smoke else 'full'}; "
          f"{n_req} reqs x {gen} tokens, {n_slots} slots, bs={bs}; fused "
          f"runs interpret-mode off-TPU):")
    base = model_bytes["gather/always"]
    for name, (tps, st) in results.items():
        mb = model_bytes[name]
        print(f"  {name:15s} {tps:9.1f} tok/s   "
              f"verified={st.kv_verified_blocks:5d} "
              f"skipped={st.kv_verify_skips:5d}   modeled HBM/step: "
              f"{mb / 1024:8.1f} KiB ({base / mb:4.2f}x vs baseline)")
    always_tps = results["gather/always"][0]
    stamped_tps = results["gather/stamped"][0]
    print(f"  stamped/always wall-clock: {stamped_tps / always_tps:.2f}x; "
          f"fused/gather modeled traffic: "
          f"{base / model_bytes['fused']:.2f}x less")
    if smoke:
        print("SMOKE OK: all backends token-identical")


if __name__ == "__main__":
    if "--prefill" in sys.argv[1:]:
        run_prefill(smoke="--smoke" in sys.argv[1:])
    else:
        run(smoke="--smoke" in sys.argv[1:])
