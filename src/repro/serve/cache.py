"""Slot-based KV-cache pool for continuous batching.

The pool holds a fixed number of request *slots*, each a full per-layer
ring KV cache (the ring semantics — ``slot = position % cache_len`` plus
``kv_positions`` mask reconstruction — already live in
``repro.models.attention``; this module only manages slot lifetime).

Device layout: the model's stacked cache pytree with the batch axis as the
slot axis, except that the per-layer position counter is widened from
``(num_layers,)`` to ``(num_layers, n_slots)`` so every slot advances
independently. The engine vmaps the decode step over the slot axis, which is
exactly what makes mixed-progress requests coexist in one fixed-shape jitted
computation.

Slot bookkeeping (free list) is host-side: admissions/evictions happen
between jitted steps, never inside them.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache


def map_kv_nodes(tree: Any, fn: Callable[[KVCache], Any]) -> Any:
    """Map ``fn`` over every KVCache node of a stacked cache pytree."""
    if isinstance(tree, KVCache):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_kv_nodes(v, fn) for k, v in tree.items()}
    raise TypeError(f"unsupported cache node {type(tree).__name__}: the "
                    "serve engine handles attention-cache families only")


class KVCachePool:
    """Fixed-capacity pool of per-request ring KV caches.

    ``state`` is the live device pytree; ``alloc``/``release`` manage the
    host-side free list; ``write_row`` scatters a freshly prefied batch-1
    cache into a slot and pins that slot's position to the request's true
    prompt length (invalidating any padded prefill slots).
    """

    def __init__(self, model, n_slots: int, cache_len: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.model = model
        self.n_slots = n_slots
        self.cache_len = cache_len
        base = model.init_cache(n_slots, cache_len=cache_len)
        # pos: (num_layers,) shared scalar -> (num_layers, n_slots) per-slot.
        self.state = map_kv_nodes(
            base, lambda c: c._replace(
                pos=jnp.zeros(c.pos.shape + (n_slots,), jnp.int32)))
        self._free: List[int] = list(range(n_slots))

    # -- host-side slot lifetime -------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)
        self._free.sort()

    # -- device-side row plumbing ------------------------------------------
    def write_row(self, slot: int, row_cache: Any, length: int) -> None:
        """Install a batch-1 prefilled cache into ``slot`` with its position
        counter rewound to ``length`` (the true, unpadded prompt length)."""

        def put(pool: KVCache, row: KVCache) -> KVCache:
            return pool._replace(
                k=pool.k.at[:, slot].set(row.k[:, 0]),
                v=pool.v.at[:, slot].set(row.v[:, 0]),
                ck=pool.ck.at[:, slot].set(row.ck[:, 0]),
                cv=pool.cv.at[:, slot].set(row.cv[:, 0]),
                pos=pool.pos.at[:, slot].set(jnp.int32(length)))

        it = iter(_kv_node_list(row_cache))
        self.state = map_kv_nodes(self.state, lambda c: put(c, next(it)))

    def vmap_axes(self) -> Any:
        """in/out_axes pytree mapping the slot axis for jax.vmap: axis 1 of
        every array leaf (axis 0 is the stacked layer axis)."""
        return jax.tree.map(lambda _: 1, self.state)


def _kv_node_list(tree: Any) -> List[KVCache]:
    acc: List[KVCache] = []
    map_kv_nodes(tree, lambda c: (acc.append(c), c)[1])
    return acc


def add_unit_batch(cache_row: Any) -> Any:
    """(layers, ...) slot slice -> (layers, 1, ...) batch-1 model cache.
    The per-layer position vector (layers,) is already what the model
    expects, so only the K/V arrays grow a batch axis."""
    return map_kv_nodes(cache_row, lambda c: c._replace(
        k=c.k[:, None], v=c.v[:, None], ck=c.ck[:, None], cv=c.cv[:, None]))


def drop_unit_batch(cache_row: Any) -> Any:
    """Inverse of :func:`add_unit_batch`."""
    return map_kv_nodes(cache_row, lambda c: c._replace(
        k=c.k[:, 0], v=c.v[:, 0], ck=c.ck[:, 0], cv=c.cv[:, 0]))
