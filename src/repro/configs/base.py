"""Config system: dataclasses describing every supported architecture.

One ``ModelConfig`` fully determines a model; ``reduced()`` derives the
CPU-smoke-test variant of the same family (tiny widths, few layers, same
structural features), per the assignment: full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    # Sliding-window attention: window size, and "every Nth layer is global"
    # (gemma3 5:1 local:global -> global_every=6; hymba: 3 full-attn layers).
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None
    causal: bool = True
    pos: str = "rope"            # "rope" | "learned" | "none"
    softmax_scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0   # kimi-style always-on shared expert(s)
    shared_d_ff: int = 0
    dense_d_ff: int = 0           # arctic-style parallel dense residual MLP
    first_k_dense: int = 0        # first k layers use a dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # Decode-serving EP layout (hillclimb): experts sharded over all devices,
    # decode tokens replicated — removes per-step expert-weight gathers.
    inference_ep: bool = False


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    kind: str                     # "mamba" | "rwkv6"
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64            # rwkv6 head size
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class FTCfg:
    """How the paper's technique is wired into this model."""

    mode: str = "correct"         # "off" | "detect" | "correct"
    stride: int = 128             # max checksum stride (8 = paper fidelity)
    block_kv: int = 512
    attn_impl: str = "efta"       # "efta" | "efta_pallas" | "flash" | "reference"
    ff_abft: bool = False         # tensor-checksum ABFT on FF/projection GEMMs
    unified: bool = True
    shadow_rowsum: bool = True
    shadow_rowmax: bool = True
    scan_unroll: bool = False     # unroll EFTA's KV scan (dry-run cost probes)
    kv_stride_override: Optional[int] = None    # pin fold widths (ablations)
    out_stride_override: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|hybrid|ssm|vlm|audio|encoder|encdec
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnCfg] = None
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # encoder-decoder (whisper/t5): decoder depth = num_layers
    encoder_layers: int = 0
    # modality frontend stub: number of precomputed embedding tokens fed to
    # cross-attention (vlm) or the encoder (audio)
    frontend_tokens: int = 0
    cross_attn_every: int = 0     # vlm: every Nth decoder layer cross-attends
    norm: str = "rmsnorm"         # "rmsnorm" | "layernorm"
    act: str = "silu"
    glu: bool = True
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    ft: FTCfg = dataclasses.field(default_factory=FTCfg)
    # "full" per-layer remat is the production default with fused attention:
    # a "dots" policy would pin the O(S*Bc) score tiles that EFTA/flash
    # deliberately keeps out of HBM (measured: whisper train 15.6 GB -> small)
    remat: str = "full"           # "none" | "dots" | "full"
    scan_layers: bool = True      # False = unroll layer stack (dry-run probes)
    # Megatron-style sequence parallelism (hillclimb): activations between
    # blocks are sharded over 'model' along the sequence axis — layer-scan
    # residuals shrink by the TP degree.
    seq_parallel: bool = False
    max_seq: int = 4096
    source: str = ""              # provenance note ([hf:...] / [arXiv:...])

    @property
    def head_dim(self) -> int:
        return self.attn.head_dim if self.attn else 0

    def param_count_estimate(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline N."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        layers = self.num_layers + self.encoder_layers
        for i in range(self.num_layers):
            n += self._block_params(i)
        for i in range(self.encoder_layers):
            n += self._enc_block_params()
        n += d  # final norm
        return n

    def _attn_params(self) -> int:
        a = self.attn
        d = self.d_model
        return (d * a.num_heads * a.head_dim            # wq
                + 2 * d * a.num_kv_heads * a.head_dim   # wk, wv
                + a.num_heads * a.head_dim * d)         # wo

    def _mlp_params(self, ff) -> int:
        mult = 3 if self.glu else 2
        return mult * self.d_model * ff

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        if s is None:
            return 0
        if s.kind == "mamba":
            di = s.expand * d
            dtr = s.dt_rank or -(-d // 16)
            return (d * 2 * di + di * s.conv_dim + di * (dtr + 2 * s.state_dim)
                    + dtr * di + di * s.state_dim + 2 * di + di * d)
        # rwkv6 time-mix + channel-mix
        return 4 * d * d + d * d + 2 * d + (2 * d * d + d * int(3.5 * d))

    def _block_params(self, i: int) -> int:
        d = self.d_model
        n = 2 * d  # norms
        if self.family == "ssm":
            return n + self._ssm_params()
        n += self._attn_params()
        if self.family == "hybrid":
            n += self._ssm_params()
        if self.cross_attn_every and (i % self.cross_attn_every
                                      == self.cross_attn_every - 1):
            n += self._attn_params() + d
        if self.moe is not None and i >= self.moe.first_k_dense:
            m = self.moe
            n += d * m.num_experts                      # router
            n += m.num_experts * self._mlp_params(m.expert_d_ff) // 1
            if m.num_shared_experts:
                n += m.num_shared_experts * self._mlp_params(m.shared_d_ff)
            if m.dense_d_ff:
                n += self._mlp_params(m.dense_d_ff)
        else:
            n += self._mlp_params(self.d_ff)
        return n

    def _enc_block_params(self) -> int:
        return 2 * self.d_model + self._attn_params() + self._mlp_params(self.d_ff)

    def active_param_count_estimate(self) -> int:
        """Active (per-token) params — MoE counts top_k + shared experts."""
        if self.moe is None:
            return self.param_count_estimate()
        m = self.moe
        full = self.param_count_estimate()
        per_expert = self._mlp_params(m.expert_d_ff)
        moe_layers = self.num_layers - m.first_k_dense
        inactive = moe_layers * (m.num_experts - m.top_k) * per_expert
        return full - inactive


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 128) -> ModelConfig:
    """Shrink a config to a CPU-runnable smoke variant of the same family."""
    def _shrink_attn(a: Optional[AttnCfg]) -> Optional[AttnCfg]:
        if a is None:
            return None
        kv = max(1, min(a.num_kv_heads, 2))
        heads = max(kv, min(a.num_heads, 4))
        heads = (heads // kv) * kv
        return dataclasses.replace(
            a, num_heads=heads, num_kv_heads=kv, head_dim=16,
            sliding_window=min(a.sliding_window, 16) if a.sliding_window else None,
            global_every=min(a.global_every, 2) if a.global_every else None)

    moe = cfg.moe
    if moe is not None:
        # capacity_factor 4.0: smoke tests check prefill/decode == full
        # forward, which requires dropless routing (capacity drops are
        # co-batch dependent and break token-level determinism).
        moe = dataclasses.replace(
            moe, num_experts=4, top_k=min(moe.top_k, 2), expert_d_ff=32,
            shared_d_ff=32 if moe.num_shared_experts else 0,
            dense_d_ff=32 if moe.dense_d_ff else 0,
            first_k_dense=min(moe.first_k_dense, 1), capacity_factor=4.0)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, state_dim=8, head_dim=16, expand=2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers if not cfg.cross_attn_every else 2 * max(
            1, min(cfg.cross_attn_every, 2)),
        cross_attn_every=min(cfg.cross_attn_every, 2) if cfg.cross_attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0,
        d_model=d_model, d_ff=4 * d_model, vocab_size=vocab,
        attn=_shrink_attn(cfg.attn), moe=moe, ssm=ssm,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        dtype="float32",
        ft=dataclasses.replace(cfg.ft, stride=8, block_kv=16),
        max_seq=64,
    )
