"""Quickstart: end-to-end fault tolerant attention in 30 lines.

Runs EFTA on random Q/K/V, injects a single-event upset into the P.V
accumulator mid-computation, and shows detection + exact correction.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (EFTAConfig, FaultSpec, Site, efta_attention,
                        reference_attention)

B, H, S, D = 2, 4, 256, 64
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)

cfg = EFTAConfig(mode="correct", stride=64, block_kv=128)
clean = reference_attention(q, k, v, causal=True)

# a soft error: the top exponent bit of one f32 accumulator element flips
# at KV block 1 (the classic silent-corruption catastrophe)
fault = FaultSpec.single(Site.GEMM2, block=1, batch=0, head=2, row=100,
                         col=17, bit=28)

protected, report = efta_attention(q, k, v, cfg=cfg, causal=True, fault=fault)
unprotected, _ = efta_attention(
    q, k, v, cfg=EFTAConfig(mode="off", stride=64, block_kv=128),
    causal=True, fault=fault)

err_p = float(jnp.max(jnp.abs(protected.astype(jnp.float32) - clean.astype(jnp.float32))))
err_u = float(jnp.max(jnp.abs(unprotected.astype(jnp.float32) - clean.astype(jnp.float32))))
print(f"max error WITH EFTA   : {err_p:.2e}")
print(f"max error WITHOUT FT  : {err_u:.2e}")
print(f"detected  [gemm1, exp, rowmax, rowsum, gemm2]: {report.detected}")
print(f"corrected [gemm1, exp, rowmax, rowsum, gemm2]: {report.corrected}")
# unprotected: visible corruption (~1e-3 for this bit/row after softmax
# normalization); protected: numerical noise, >3 orders of magnitude better
assert err_p < 1e-4 and err_u > 1e-3 and err_u > 1000 * err_p
print("OK: the SEU was detected and corrected inside the fused attention.")
