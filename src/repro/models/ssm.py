"""State-space sequence mixers: Mamba (selective SSM, for Hymba's parallel
heads) and RWKV6 "Finch" (data-dependent decay).

Both are attention-free: the paper's checksum ABFT has no GEMM-of-scores to
protect here (DESIGN.md §Arch-applicability). The projection GEMMs can be
ABFT-protected (``ff_abft``) and the recurrent state update is protected by
range restriction in the SNVR spirit (finite-state check).

Recurrences run as ``lax.scan`` over time with f32 state (compact HLO for the
dry-run; a chunked/associative formulation is a recorded hillclimb lever).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    h: jax.Array      # (B, d_inner, N) f32
    conv: jax.Array   # (B, K-1, d_inner) — trailing inputs for the causal conv


def mamba_init(key, d: int, s: SSMCfg, dtype):
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, di), jnp.float32)
                   / math.sqrt(s.conv_dim)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * s.state_dim, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def mamba_state_init(batch: int, d: int, s: SSMCfg, dtype) -> MambaState:
    di = s.expand * d
    return MambaState(
        h=jnp.zeros((batch, di, s.state_dim), jnp.float32),
        conv=jnp.zeros((batch, s.conv_dim - 1, di), dtype))


def _mamba_conv(xh, conv_w, conv_b, prefix):
    """Causal depthwise conv via K shifted adds. xh: (B, S, di)."""
    k = conv_w.shape[0]
    full = jnp.concatenate([prefix.astype(xh.dtype), xh], axis=1)
    s = xh.shape[1]
    out = jnp.zeros_like(xh, dtype=jnp.float32)
    for i in range(k):
        out = out + full[:, i:i + s, :].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    return (out + conv_b.astype(jnp.float32)).astype(xh.dtype)


def mamba_apply(params, x, s: SSMCfg, *, state: MambaState | None = None):
    """x: (B, S, d) -> (y, new_state). Selective scan over time."""
    b, seq, d = x.shape
    di = s.expand * d
    dtr = params["dt_proj"].shape[0]
    if state is None:
        state = mamba_state_init(b, d, s, x.dtype)

    xz = jnp.matmul(x, params["in_proj"], preferred_element_type=jnp.float32)
    xh_pre, z = jnp.split(xz.astype(x.dtype), 2, axis=-1)
    xh = jax.nn.silu(_mamba_conv(xh_pre, params["conv_w"], params["conv_b"],
                                 state.conv))
    # conv state carries the *pre-conv* inputs (the conv window operates on
    # in_proj outputs, not on activated conv outputs)
    new_conv = jnp.concatenate([state.conv.astype(x.dtype), xh_pre],
                               axis=1)[:, -(s.conv_dim - 1):, :]

    dbc = jnp.matmul(xh, params["x_proj"], preferred_element_type=jnp.float32)
    dt_r, b_c, c_c = jnp.split(dbc, [dtr, dtr + s.state_dim], axis=-1)
    dt = jax.nn.softplus(
        jnp.matmul(dt_r.astype(x.dtype), params["dt_proj"],
                   preferred_element_type=jnp.float32)
        + params["dt_bias"].astype(jnp.float32))                  # (B,S,di)
    a = -jnp.exp(params["A_log"])                                 # (di, N)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp                                 # (B,di),(B,N),(B,N),(B,di)
        da = jnp.exp(dt_t[..., None] * a)                         # (B,di,N)
        h = da * h + dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    xs = (dt.transpose(1, 0, 2), b_c.transpose(1, 0, 2),
          c_c.transpose(1, 0, 2), xh.astype(jnp.float32).transpose(1, 0, 2))
    h_f, ys = jax.lax.scan(step, state.h, xs)
    y = ys.transpose(1, 0, 2) + params["D"] * xh.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.matmul(y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, MambaState(h=h_f, conv=new_conv)


# ---------------------------------------------------------------------------
# RWKV6 (Finch): token shift + data-dependent decay
# ---------------------------------------------------------------------------

class RWKVState(NamedTuple):
    wkv: jax.Array     # (B, H, hd, hd) f32
    x_prev: jax.Array  # (B, d)  — token shift for time-mix
    x_prev_c: jax.Array  # (B, d) — token shift for channel-mix


def rwkv6_init(key, d: int, s: SSMCfg, dtype):
    h = d // s.head_dim
    lora = 64
    ks = jax.random.split(key, 12)
    ffd = int(3.5 * d)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "w_base": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": dense_init(ks[1], d, lora, dtype),
        "w_lora_b": (jnp.zeros((lora, d))).astype(dtype),
        "wr": dense_init(ks[2], d, d, dtype),
        "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype),
        "wg": dense_init(ks[5], d, d, dtype),
        "u": (jax.random.normal(ks[6], (h, s.head_dim), jnp.float32) * 0.1),
        "wo": dense_init(ks[7], d, d, dtype),
        "ln_x": jnp.ones((d,), jnp.float32),
        # channel mix
        "mu_c": (jax.random.uniform(ks[8], (2, d), jnp.float32)).astype(dtype),
        "wk_c": dense_init(ks[9], d, ffd, dtype),
        "wv_c": dense_init(ks[10], ffd, d, dtype),
        "wr_c": dense_init(ks[11], d, d, dtype),
    }


def rwkv_state_init(batch: int, d: int, s: SSMCfg, dtype) -> RWKVState:
    h = d // s.head_dim
    return RWKVState(
        wkv=jnp.zeros((batch, h, s.head_dim, s.head_dim), jnp.float32),
        x_prev=jnp.zeros((batch, d), dtype),
        x_prev_c=jnp.zeros((batch, d), dtype))


def _shifted(x, x_prev):
    """(B,S,d) -> previous-token tensor, seeded by carry x_prev (B,d)."""
    return jnp.concatenate([x_prev[:, None, :].astype(x.dtype),
                            x[:, :-1, :]], axis=1)


def rwkv6_time_mix(params, x, s: SSMCfg, *, state: RWKVState):
    b, seq, d = x.shape
    nh, hd = d // s.head_dim, s.head_dim
    xs = _shifted(x, state.x_prev)
    mu = params["mu"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    def mix(i):
        return (xf + mu[i] * (xsf - xf)).astype(x.dtype)
    r = jnp.matmul(mix(0), params["wr"]).reshape(b, seq, nh, hd)
    k = jnp.matmul(mix(1), params["wk"]).reshape(b, seq, nh, hd)
    v = jnp.matmul(mix(2), params["wv"]).reshape(b, seq, nh, hd)
    g = jnp.matmul(mix(3), params["wg"])
    # data-dependent decay (the Finch contribution)
    w_dd = (params["w_base"]
            + jnp.matmul(jnp.tanh(jnp.matmul(mix(4), params["w_lora_a"])),
                         params["w_lora_b"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_dd)).reshape(b, seq, nh, hd)           # in (0,1)
    u = params["u"]

    def step(wkv, inp):
        r_t, k_t, v_t, w_t = [t.astype(jnp.float32) for t in inp]  # (B,nh,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]                 # (B,nh,hd,hd)
        y_t = jnp.einsum("bhj,bhji->bhi", r_t, wkv + u[None, :, :, None] * kv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, y_t

    xs_scan = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
               v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    wkv_f, ys = jax.lax.scan(step, state.wkv, xs_scan)
    y = ys.transpose(1, 0, 2, 3).reshape(b, seq, d)
    # group norm over heads
    yg = y.reshape(b, seq, nh, hd)
    yg = (yg - yg.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yg.var(-1, keepdims=True) + 1e-5)
    y = (yg.reshape(b, seq, d) * params["ln_x"]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = jnp.matmul(y, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_state = state._replace(wkv=wkv_f, x_prev=x[:, -1, :])
    return out, new_state


def rwkv6_channel_mix(params, x, *, state: RWKVState):
    xs = _shifted(x, state.x_prev_c)
    mu = params["mu_c"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    xk = (xf + mu[0] * (xsf - xf)).astype(x.dtype)
    xr = (xf + mu[1] * (xsf - xf)).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.matmul(xk, params["wk_c"])))
    out = jax.nn.sigmoid(jnp.matmul(xr, params["wr_c"])) * jnp.matmul(
        kk, params["wv_c"], preferred_element_type=jnp.float32).astype(x.dtype)
    return out, state._replace(x_prev_c=x[:, -1, :])
