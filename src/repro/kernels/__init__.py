from repro.kernels.efta_attention import efta_attention_pallas
from repro.kernels.ops import attention, attention_jit
