"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax

from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array
    # error-feedback residuals for compressed cross-pod gradient sync
    # (empty dict when pod_sync="dense")
    ef: Any = None
