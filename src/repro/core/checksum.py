"""Tensor-checksum algebra (paper §4.1, adapted to TPU tile layout).

The paper's tensor checksum folds a matrix along one dimension with a fixed
stride ``s`` chosen to match the compute unit's native data layout, so that
encode / verify / correct are *local* accumulations:

  * A100 (paper): ``s = 8`` matches the ``SM80_16x8x16`` MMA atom N-dim — each
    CUDA thread folds only its own registers.
  * TPU (this repo): ``s = 128`` matches the VREG lane tile — folding
    ``(Br, Bc) -> (Br, Bc//s, s) -> sum(axis=1)`` is a sum of whole vregs with
    zero cross-lane shuffles. ``s = 8`` remains available for paper-fidelity
    experiments (``paper_stride``).

Given a fold with ``g = width // s`` segments:

  ``fold1(X)[i, j] = sum_l X[i, j + s*l]``              (weights r1 = 1)
  ``fold2(X)[i, j] = sum_l (l+1) * X[i, j + s*l]``      (weights r2 = l+1)

The key ABFT identity: for ``S = Q @ K^T``,
``fold1(S) = Q @ fold1_rows(K^T) = Q @ encode_checksum1(K)^T`` — so checksums
of the *inputs* predict folds of the *output*, and a mismatch between the
predicted fold (``S_check``) and the recomputed fold (``S_sum``) localizes and
corrects single errors per (row, fold column) at stride ``s``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

PAPER_STRIDE = 8     # SM80 MMA atom N-dim (paper fidelity)
TPU_STRIDE = 128     # TPU VREG lane tile (architecture-aware default here)


def _check_fold(width: int, stride: int) -> int:
    if width % stride != 0:
        raise ValueError(f"fold width {width} not divisible by stride {stride}")
    return width // stride


def fold1(x: jax.Array, stride: int) -> jax.Array:
    """Unweighted strided fold along the last dim: (..., W) -> (..., stride)."""
    g = _check_fold(x.shape[-1], stride)
    return x.reshape(*x.shape[:-1], g, stride).sum(axis=-2)


def fold2(x: jax.Array, stride: int) -> jax.Array:
    """Index-weighted strided fold along the last dim (weights l+1)."""
    g = _check_fold(x.shape[-1], stride)
    w = jnp.arange(1, g + 1, dtype=x.dtype)
    return (x.reshape(*x.shape[:-1], g, stride) * w[:, None]).sum(axis=-2)


def foldprod(x: jax.Array, stride: int) -> jax.Array:
    """Strided product fold along the last dim — used for the EXP identity
    ``exp(fold1(S) - g*m) == prod_l exp(S[..., j+s*l] - m)`` (paper Alg.1 l.13)."""
    g = _check_fold(x.shape[-1], stride)
    return x.reshape(*x.shape[:-1], g, stride).prod(axis=-2)


class Checksums(NamedTuple):
    """Pair of fold checksums (unweighted, index-weighted) of one operand."""

    c1: jax.Array
    c2: jax.Array


def encode_kv(x: jax.Array, stride: int) -> Checksums:
    """Encode checksums of a K or V block along its *sequence/feature* axis.

    For ``K`` of shape (..., Bc, d) folded along ``Bc`` (axis -2): returns
    checksums of shape (..., stride, d) such that
    ``Q @ c1.T == fold1(Q @ K^T)`` along the Bc axis.

    Folds accumulate in f32 and are rounded ONCE to the storage dtype: the
    paper's in-precision (fp16) encode accumulates rounding into the checksum
    and forces loose thresholds (their 0.48); a single rounding leaves
    ~2^-8 relative error and lets thresholds tighten 2-10x.
    """
    g = _check_fold(x.shape[-2], stride)
    xr = x.astype(jnp.float32).reshape(*x.shape[:-2], g, stride, x.shape[-1])
    c1 = xr.sum(axis=-3)
    w = jnp.arange(1, g + 1, dtype=jnp.float32)
    c2 = (xr * w[:, None, None]).sum(axis=-3)
    return Checksums(c1.astype(x.dtype), c2.astype(x.dtype))


def encode_cols(x: jax.Array, stride: int) -> Checksums:
    """Encode checksums of V along its *feature* axis (last dim).

    For ``V`` of shape (..., Bc, d) folded along ``d``: returns (..., Bc, stride)
    such that ``P @ c1 == fold1(P @ V)`` along the d axis. f32 accumulation,
    single rounding (see encode_kv).
    """
    xf = x.astype(jnp.float32)
    return Checksums(fold1(xf, stride).astype(x.dtype),
                     fold2(xf, stride).astype(x.dtype))


def encode_kv_tile(x: jax.Array, stride: int) -> Checksums:
    """Block-granular :func:`encode_kv` for a single streamed (Bs, d) tile.

    Mathematically identical to ``encode_kv`` (f32 accumulation over
    ``g = Bs // stride`` segments, weights ``l + 1``) but built from static
    strided slices and python-float weights so it lowers inside a Pallas
    kernel body — ``encode_kv``'s ``jnp.arange`` weight vector would be a
    captured constant, which ``pallas_call`` rejects. This is the fold the
    fused paged-attention kernel recomputes in its KV streaming loop to
    verify each resident block in the same pass that consumes it.
    """
    g = _check_fold(x.shape[-2], stride)
    c1 = jnp.zeros(x.shape[:-2] + (stride, x.shape[-1]), jnp.float32)
    c2 = jnp.zeros_like(c1)
    for l in range(g):
        seg = x[..., l * stride:(l + 1) * stride, :].astype(jnp.float32)
        c1 = c1 + seg
        c2 = c2 + float(l + 1) * seg
    return Checksums(c1, c2)


def kv_block_threshold(dtype) -> float:
    """Default relative threshold for resident-KV block verification.

    Shared between the engine's gather-time :func:`verify_block` and the
    fused paged-attention kernel's in-loop verify so both backends flag
    exactly the same corruptions: encode accumulates in f32 and rounds once
    to the storage dtype, leaving ~2^-8 relative error in bf16 (vs ~2^-24
    in f32), hence the two tiers.
    """
    return 1e-3 if jnp.dtype(dtype) == jnp.float32 else 5e-2


def block_fold_bad(
    fresh: Checksums,
    stored: Checksums,
    *,
    threshold: float,
) -> jax.Array:
    """Compare a freshly recomputed fold pair against the resident pair.

    ``fresh``/``stored``: (..., stride, d) checksum planes. Returns ``bad``
    bool (...,) per block, reduced over the (stride, d) plane. The relative
    threshold carries a per-block magnitude floor (mean |c|), same rationale
    as :func:`verify_and_correct`: verify-side rounding scales with the fold
    magnitude even where an individual checksum lands near zero. The negated
    ``<=`` form makes NaN/inf deltas (exponent-bit corruption) count as
    mismatches. This is the *single* definition of "block checksum mismatch":
    the gather path folds full pools through it and the fused Pallas kernel
    calls it on one streamed (stride, d) tile at a time.
    """
    c1 = stored.c1.astype(jnp.float32)
    c2 = stored.c2.astype(jnp.float32)
    floor1 = jnp.maximum(jnp.mean(jnp.abs(c1), axis=(-2, -1), keepdims=True),
                         1e-6)
    floor2 = jnp.maximum(jnp.mean(jnp.abs(c2), axis=(-2, -1), keepdims=True),
                         1e-6)
    ok1 = jnp.abs(c1 - fresh.c1.astype(jnp.float32)) \
        <= threshold * jnp.maximum(jnp.abs(c1), floor1)
    ok2 = jnp.abs(c2 - fresh.c2.astype(jnp.float32)) \
        <= threshold * jnp.maximum(jnp.abs(c2), floor2)
    return ~jnp.all(ok1 & ok2, axis=(-2, -1))


def verify_block(
    x: jax.Array,
    checks: Checksums,
    stride: int,
    *,
    threshold: float,
) -> tuple[jax.Array, jax.Array]:
    """Integrity check of a *stored* KV block against its resident checksums.

    ``x``: block data (..., Bs, d); ``checks``: the :func:`encode_kv` pair
    computed when the block was last written, shape (..., stride, d). Unlike
    the GEMM-identity verifications this is a memory check: the fold is
    recomputed from the resident data and compared against the stored fold,
    so any SEU in the block (or in the checksum itself) since the last write
    shows up as a mismatch. Both folds are verified — a single bit flip can
    never cancel in both the unweighted and index-weighted sums.

    Returns (``bad`` bool (...,) per block — reduced over the (stride, d)
    checksum plane, NaN-safe — and the total mismatch count).
    """
    fresh = encode_kv(x.astype(jnp.float32), stride)
    bad = block_fold_bad(fresh, checks, threshold=threshold)
    return bad, bad.sum(dtype=jnp.int32)


class Verdict(NamedTuple):
    """Outcome of a checksum verification over one tensor."""

    corrected: jax.Array   # the (possibly) corrected tensor
    n_detected: jax.Array  # int32 scalar: # of (row, fold-col) mismatches
    max_delta: jax.Array   # f32 scalar: largest |checksum - recomputed fold|


def verify_and_correct(
    x: jax.Array,
    checks: Checksums,
    stride: int,
    *,
    threshold: float,
    correct: bool = True,
) -> Verdict:
    """Detect + locate + correct single errors per (row, fold column).

    ``x``: (..., W); ``checks.c1/c2``: predicted folds of shape (..., stride).
    An error at ``x[..., j + s*l]`` of magnitude ``delta`` shows up as
    ``c1 - fold1 = -delta`` at fold column j and ``(c2 - fold2)/(c1 - fold1)
    = l+1`` locates the segment. Correction adds ``delta`` back (paper §4.1).
    """
    g = _check_fold(x.shape[-1], stride)
    xf = x.astype(jnp.float32)
    sum1 = fold1(xf, stride)
    sum2 = fold2(xf, stride)
    d1 = checks.c1.astype(jnp.float32) - sum1
    d2 = checks.c2.astype(jnp.float32) - sum2
    # threshold is relative to the checksum magnitude, floored at the tensor's
    # mean |c1|: verify-side rounding scales with the *contraction* magnitude
    # even where an individual checksum lands near zero, so a unit floor
    # false-positives and an absolute threshold can't fit all fold widths.
    c1f = jnp.abs(checks.c1.astype(jnp.float32))
    floor = jnp.maximum(jnp.mean(c1f), 1e-6)
    # negated-<= form so a NaN/inf delta (exponent-bit corruption that blew
    # up the fold) counts as detected rather than comparing False
    bad = ~(jnp.abs(d1) <= threshold * jnp.maximum(c1f, floor))
    n_detected = bad.sum(dtype=jnp.int32)
    max_delta = jnp.max(jnp.abs(d1)) if d1.size else jnp.float32(0)
    if not correct:
        return Verdict(x, n_detected, max_delta)
    # Locate segment index l* = round(d2/d1) - 1, clamped to [0, g-1].
    safe_d1 = jnp.where(bad, d1, 1.0)
    l_star = jnp.clip(jnp.round(d2 / safe_d1) - 1, 0, g - 1).astype(jnp.int32)
    seg = jnp.arange(g, dtype=jnp.int32)
    # one-hot over segments, broadcast over fold columns: (..., g, stride)
    onehot = (seg[:, None] == l_star[..., None, :]).astype(jnp.float32)
    patch = onehot * (d1 * bad)[..., None, :]
    fixed = xf.reshape(*xf.shape[:-1], g, stride) + patch
    fixed = fixed.reshape(x.shape).astype(x.dtype)
    return Verdict(fixed, n_detected, max_delta)


# f32 exp() leaves the normal range below log(2^-126) ~= -87.3 — XLA flushes
# subnormals to zero, so log(exp(x)) becomes -inf there. Entries deeper than
# this floor have no faithful log-domain image in P and are excluded from the
# log check (they are <= 1e-38 attention weights either way).
LOG_PROD_FLOOR = -87.0


def verify_product_log(
    p: jax.Array,
    log_check1: jax.Array,
    stride: int,
    *,
    threshold: float,
) -> tuple[jax.Array, jax.Array]:
    """Log-domain EXP-stage verification (detect-mode coverage closure).

    The linear fold-*product* check (:func:`verify_product`) goes blind when
    any segment of a fold column underflows: ``prod ~ 0`` and ``check ~ 0``
    compare equal no matter what happened to the *other* (possibly large)
    entries of that column. Comparing in the log domain turns the product
    into a sum — ``fold1(log P) == S_check1 - g*m`` — which stays exact down
    to the f32 normal-range floor, so a corrupted ``P[i] = 0.9 -> 0`` in a
    column whose product underflows is still a ~87-nat mismatch.

    ``p``: exp outputs (..., W) > 0; ``log_check1``: predicted log-domain fold
    (..., stride), i.e. ``S_check1 - g*m`` (with the same cap as P, if any).
    The threshold is *absolute in nats* relative to ``max(|check|, 1)`` —
    equivalent to a relative tolerance on the linear product. NaN/negative
    corruptions (sign-bit flips) propagate to NaN and count as detected via
    the negated comparison.
    """
    logp = jnp.log(p.astype(jnp.float32))          # -inf for p == 0, nan for p < 0
    logp = jnp.maximum(logp, LOG_PROD_FLOOR)       # nan propagates
    fold = fold1(logp, stride)
    ref = jnp.maximum(jnp.abs(log_check1.astype(jnp.float32)), 1.0)
    bad = ~(jnp.abs(fold - log_check1.astype(jnp.float32)) <= threshold * ref)
    return bad, bad.sum(dtype=jnp.int32)


def verify_product(
    p: jax.Array,
    p_check1: jax.Array,
    stride: int,
    *,
    threshold: float,
) -> tuple[jax.Array, jax.Array]:
    """EXP-stage verification (paper Alg.1 line 13): compare the strided
    *product* of ``P = exp(S - m)`` against ``exp(S_check1 - g*m)``.

    The comparison is *relative* (products span many orders of magnitude);
    mismatches below ``threshold * |check|`` or in the denormal floor are
    ignored — such errors correspond to negligible attention probabilities.

    Returns (bad bool (..., stride) per fold column, n_detected).
    """
    floor = 1e-20
    prod = foldprod(p.astype(jnp.float32), stride)
    ref = jnp.maximum(jnp.abs(p_check1.astype(jnp.float32)), floor)
    bad = jnp.abs(prod - p_check1.astype(jnp.float32)) > threshold * ref + floor
    return bad, bad.sum(dtype=jnp.int32)


# --- traditional (rank-1) ABFT, used by the decoupled baseline -------------


def traditional_encode_rows(a: jax.Array) -> jax.Array:
    """Classic ABFT column checksums: append [1-weighted; index-weighted] rows.

    a: (..., M, K) -> (..., 2, K) with c1 = ones @ A, c2 = (1..M) @ A.
    f32 accumulation, single rounding (see encode_kv).
    """
    af = a.astype(jnp.float32)
    m = a.shape[-2]
    w = jnp.arange(1, m + 1, dtype=jnp.float32)
    c1 = af.sum(axis=-2, keepdims=True)
    c2 = (af * w[..., :, None]).sum(axis=-2, keepdims=True)
    return jnp.concatenate([c1, c2], axis=-2).astype(a.dtype)


def traditional_encode_cols(b: jax.Array) -> jax.Array:
    """Classic ABFT row checksums: append [B@1, B@(1..N)] columns."""
    bf = b.astype(jnp.float32)
    n = b.shape[-1]
    w = jnp.arange(1, n + 1, dtype=jnp.float32)
    r1 = bf.sum(axis=-1, keepdims=True)
    r2 = (bf * w).sum(axis=-1, keepdims=True)
    return jnp.concatenate([r1, r2], axis=-1).astype(b.dtype)


def traditional_verify_correct(
    c: jax.Array,
    row_checks: jax.Array,
    *,
    threshold: float,
    correct: bool = True,
) -> Verdict:
    """Verify/correct ``C`` against classic row checksums (C @ [1, w]).

    row_checks: (..., M, 2) — predicted [sum, weighted-sum] per row.
    Single-error model: a bad row is located to a column by the weighted ratio.
    """
    n = c.shape[-1]
    cf = c.astype(jnp.float32)
    w = jnp.arange(1, n + 1, dtype=jnp.float32)
    s1 = cf.sum(axis=-1)
    s2 = (cf * w).sum(axis=-1)
    d1 = row_checks[..., 0].astype(jnp.float32) - s1
    d2 = row_checks[..., 1].astype(jnp.float32) - s2
    c1f = jnp.abs(row_checks[..., 0].astype(jnp.float32))
    floor = jnp.maximum(jnp.mean(c1f), 1e-6)
    bad = ~(jnp.abs(d1) <= threshold * jnp.maximum(c1f, floor))  # NaN-safe
    n_detected = bad.sum(dtype=jnp.int32)
    max_delta = jnp.max(jnp.abs(d1)) if d1.size else jnp.float32(0)
    if not correct:
        return Verdict(c, n_detected, max_delta)
    safe_d1 = jnp.where(bad, d1, 1.0)
    col = jnp.clip(jnp.round(d2 / safe_d1) - 1, 0, n - 1).astype(jnp.int32)
    onehot = (jnp.arange(n, dtype=jnp.int32) == col[..., None]).astype(jnp.float32)
    fixed = cf + onehot * (d1 * bad)[..., None]
    return Verdict(fixed.astype(c.dtype), n_detected, max_delta)
