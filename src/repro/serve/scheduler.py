"""Continuous-batching request scheduler (FCFS, iteration-level).

Orca-style iteration scheduling: at *every* decode step the scheduler first
evicts finished requests (EOS or token budget), then admits waiting requests
into freed cache slots. Admission and eviction are host-side decisions made
between jitted decode steps; the decode computation itself always runs at the
full fixed slot count with finished/empty slots masked out.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.sampling import SamplingParams


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its lifetime bookkeeping."""

    rid: int
    prompt: np.ndarray                    # (T,) int32
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    # number of engine decode-step retries this request sat through
    retries: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def num_generated(self) -> int:
        return len(self.generated)

    def is_done(self) -> bool:
        if self.num_generated >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.generated
                and self.generated[-1] == self.eos_id)


@dataclasses.dataclass
class ScheduleDecision:
    admitted: List[Request]
    evicted: List[Request]


class ContinuousBatchingScheduler:
    """FCFS admission over a fixed slot budget."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.waiting: Deque[Request] = collections.deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self.finished: List[Request] = []

    def add(self, req: Request) -> None:
        if req.state is not RequestState.WAITING:
            raise ValueError(f"request {req.rid} already scheduled")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def step(self, alloc_slot, release_slot) -> ScheduleDecision:
        """One scheduling iteration. ``alloc_slot``/``release_slot`` are the
        cache pool's slot allocator callbacks."""
        evicted: List[Request] = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.is_done():
                req.state = RequestState.FINISHED
                del self.running[slot]
                release_slot(slot)
                req.slot = None
                self.finished.append(req)
                evicted.append(req)

        admitted: List[Request] = []
        while self.waiting:
            slot = alloc_slot()
            if slot is None:
                break
            req = self.waiting.popleft()
            req.slot = slot
            req.state = RequestState.RUNNING
            self.running[slot] = req
            admitted.append(req)
        return ScheduleDecision(admitted=admitted, evicted=evicted)

    def active_rows(self) -> Sequence[Request]:
        return [self.running[s] for s in sorted(self.running)]
