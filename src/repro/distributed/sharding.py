"""Logical-axis sharding rules (FSDP x TP x EP), MaxText-style but by name.

Parameters are sharded over BOTH the ``data`` axis (FSDP/ZeRO-3 storage — the
1T-class MoE archs do not fit otherwise) and the ``model`` axis (tensor /
expert parallel). GSPMD inserts the just-in-time all-gathers for dense
layers; the MoE layer gathers explicitly inside its shard_map.

Rules are matched on parameter-path suffixes; stacked-scan leading layer dims
are padded with None automatically.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# name-pattern -> spec for the *trailing* dims of the parameter
_RULES: list[tuple[str, tuple]] = [
    # vocab-parallel embedding (Megatron-style): GSPMD emits masked-gather +
    # all-reduce for the lookup. Double-sharding (model,data) triggers XLA's
    # "involuntary full rematerialization" slow path on the 3-axis mesh.
    (r"(embed|lm_head)/table$", ("model", None)),
    (r"pos/pos$", (None, None)),
    # attention / dense projections: column-parallel in, row-parallel out
    (r"(wq|wk|wv)$", ("data", "model")),
    (r"wo$", ("model", "data")),
    (r"(gate|up)$", ("data", "model")),
    (r"down$", ("model", "data")),
    # MoE experts: E over model, dim-1 over data (FSDP); router replicated
    (r"moe/(wg|wu|wd)$", ("model", "data", None)),
    (r"moe/router$", (None, None)),
    # SSM projections
    (r"(in_proj|x_proj|dt_proj|wr|wg|wk|wv|wk_c|wr_c|w_lora_a)$",
     ("data", "model")),
    (r"(out_proj|wv_c|w_lora_b)$", ("model", "data")),
    (r"A_log$", (None, None)),
]


# Inference layout (hillclimb, §Perf): decode gathers FSDP-sharded weights
# EVERY step for a handful of tokens — ruinous. Dense weights go pure-TP
# (they fit HBM without the optimizer state); expert weights shard E over
# 'data' and the ff dim over 'model' so the expert matmul needs NO weight
# gather (tokens are all-gathered instead — KB vs GB at decode batch sizes).
_INFERENCE_RULES: list[tuple[str, tuple]] = [
    (r"(embed|lm_head)/table$", ("model", None)),
    (r"pos/pos$", (None, None)),
    (r"(wq|wk|wv)$", (None, "model")),
    (r"wo$", ("model", None)),
    (r"(gate|up)$", (None, "model")),
    (r"down$", ("model", None)),
    (r"moe/(wg|wu)$", ("data", None, "model")),
    (r"moe/wd$", ("data", "model", None)),
    (r"moe/router$", (None, None)),
    (r"(in_proj|x_proj|dt_proj|wr|wg|wk|wv|wk_c|wr_c|w_lora_a)$",
     (None, "model")),
    (r"(out_proj|wv_c|w_lora_b)$", ("model", None)),
    (r"A_log$", (None, None)),
]


def spec_for_param(path: str, ndim: int, *, inference: bool = False) -> P:
    rules = _INFERENCE_RULES if inference else _RULES
    for pat, spec in rules:
        if re.search(pat, path):
            spec = tuple(spec)
            if len(spec) > ndim:
                spec = spec[-ndim:]
            pad = (None,) * (ndim - len(spec))
            return P(*(pad + spec))
    return P(*((None,) * ndim))


def _mesh_filter(spec: P, mesh) -> P:
    """Drop axes not present in the mesh (e.g. 'pod' on single-pod)."""
    def ok(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x in mesh.shape)
            return kept if kept else None
        return a if a in mesh.shape else None
    return P(*(ok(a) for a in spec))


def param_shardings(params_shape, mesh, *, inference: bool = False):
    """Map an eval_shape'd param pytree to NamedShardings by path rules."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        spec = _mesh_filter(
            spec_for_param(name, len(leaf.shape), inference=inference), mesh)
        # Never shard a dim that the mesh axis doesn't divide reasonably —
        # GSPMD pads, which is fine for model dims but wasteful for tiny ones.
        spec = _drop_tiny(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _drop_tiny(spec: P, shape, mesh) -> P:
    # jit *input* shardings must divide dimensions exactly (GSPMD pads only
    # internal values) — drop axes that don't divide (e.g. whisper's 51865
    # vocab stays replicated; the big 128k-262k vocabs shard cleanly).
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if (dim >= size and dim % size == 0) else None)
    return P(*fixed)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh, ndim: int):
    dp = dp_axes(mesh)
    return NamedSharding(mesh, P(dp if dp else None,
                                 *([None] * (ndim - 1))))


def cache_shardings(cache_shape, mesh, *, batch: int):
    """KV caches: batch over dp when divisible, else shard the sequence axis
    over 'data' (context parallelism for long_500k decode)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        nd = len(leaf.shape)
        if leaf.shape and leaf.shape[-1] == 0:
            return NamedSharding(mesh, P())
        if "ck" in name or "cv" in name or nd < 3:
            return NamedSharding(mesh, P())
        # stacked (L, B, H, S, D) attention caches / (L, B, ...) ssm states;
        # the VLM's superblock nesting adds leading dims, so locate the batch
        # dim by size (first match from the left past the stack dim).
        bidx = next((i for i in range(1, nd) if leaf.shape[i] == batch), None)
        if (batch >= dp_size and bidx is not None
                and leaf.shape[bidx] % dp_size == 0 and dp):
            spec = [None] * nd
            spec[bidx] = dp
            return NamedSharding(mesh, P(*spec))
        if (nd >= 4 and "data" in mesh.shape
                and leaf.shape[-2] % mesh.shape["data"] == 0):
            # (L, B, Hkv, S, D): context-parallel over the seq axis (long
            # decode). Small recurrent states (non-divisible) stay replicated.
            spec = [None] * nd
            spec[-2] = "data"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])
