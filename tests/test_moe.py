"""MoE layer: routing determinism, capacity behaviour, dense-loop equiv."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoECfg
from repro.models.moe import moe_apply, moe_init

pytestmark = pytest.mark.quick


def setup(cf=8.0):
    m = MoECfg(num_experts=8, top_k=2, expert_d_ff=32, capacity_factor=cf)
    params = moe_init(jax.random.PRNGKey(0), 16, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    return m, params, x


def test_matches_dense_loop():
    m, params, x = setup()
    y, _ = moe_apply(params, x, m)
    x2 = np.asarray(x.reshape(-1, 16))
    logits = x2 @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    idx = np.argsort(-probs, -1)[:, :2]
    ref = np.zeros_like(x2)
    for t in range(x2.shape[0]):
        ws = probs[t, idx[t]]
        ws = ws / ws.sum()
        for j, e in enumerate(idx[t]):
            h = np.asarray(jax.nn.silu(x2[t] @ params["wg"][e])) * (
                x2[t] @ np.asarray(params["wu"][e]))
            ref[t] += ws[j] * (h @ np.asarray(params["wd"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 16), ref, atol=1e-4)


def test_capacity_drops_bounded():
    m, params, x = setup(cf=0.25)  # tiny capacity: many drops, still finite
    y, aux = moe_apply(params, x, m)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(y).max()) < 1e3


def test_aux_loss_near_one_for_uniform():
    m, params, x = setup()
    _, aux = moe_apply(params, x, m)
    assert 0.5 < float(aux) < 4.0  # E * sum f_e p_e ~ 1 for balanced routing


def test_grad_flows():
    m, params, x = setup()
    g = jax.grad(lambda p: moe_apply(p, x, m)[0].sum())(params)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["router"]).sum()) > 0  # router receives gradient
