"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec; conv frontend is a stub providing precomputed frame
embeddings (1500 frames). [arXiv:2212.04356; unverified]"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, d_model=512, d_ff=2048, vocab_size=51865,
    attn=AttnCfg(num_heads=8, num_kv_heads=8, head_dim=64, pos="learned"),
    frontend_tokens=1500, norm="layernorm", glu=False, act="gelu",
    source="arXiv:2212.04356",
)
