import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Each cell produces:
  1. CERTIFICATION - the full-depth model (layer stack as lax.scan) lowers and
     compiles for the production mesh; ``memory_analysis()`` gives per-device
     bytes (fits 16 GB HBM?).
  2. ROOFLINE TERMS - XLA's cost_analysis counts while-loop bodies ONCE
     (verified empirically), so per-layer costs are extracted from two small
     UNROLLED probe compiles (depth k1 and k2 = k1 + period) and extrapolated:
         total(L) = F(k1) + n_periods * (F(k2) - F(k1))
     Probe depths are flag-aware (gemma's 5:1 local:global period, hymba's 3
     fixed full-attention layers, the VLM's cross-attn superblock) so the
     period difference captures exactly one structural repeat.
     Known accounting gap: SSM per-timestep recurrences stay inside a while
     body (undercount ~1-5% of SSM-arch FLOPs; projections dominate).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, resumable
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.distributed.sharding import (batch_sharding, cache_shardings,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.optim.adamw import AdamW, warmup_cosine
from repro.train.step import make_train_step
from repro.train.state import TrainState
from repro.utils.hlo import collective_bytes, count_ops

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def cell_config(arch: str, shape_name: str):
    """The cell's model config (with dry-run-appropriate FT block size)."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        # 500k-token KV scan at Bc=512 would be 1024 loop steps; larger
        # blocks keep probe unrolls tractable and cut checksum width ratio.
        cfg = dataclasses.replace(
            cfg, ft=dataclasses.replace(cfg.ft, block_kv=32768))
    if shape_name in ("prefill_32k", "decode_32k"):
        cfg = dataclasses.replace(
            cfg, ft=dataclasses.replace(cfg.ft, block_kv=2048))
    return cfg


def probe_plan(cfg):
    """(k1, k2, n_periods) such that total = F(k1) + n_periods*(F(k2)-F(k1)).

    Probe depths keep the count of structurally-special layers equal so the
    difference is exactly one period of ordinary layers.
    """
    L = cfg.num_layers
    if cfg.family == "vlm" and cfg.cross_attn_every:
        ce = cfg.cross_attn_every
        return ce, 2 * ce, (L - ce) // ce
    if cfg.family == "hybrid":
        # full-attn at {0, mid, last}: any k >= 4 has exactly 3 globals
        # (F(5)-F(4) isolates one pure sliding-window layer)
        k1 = min(4, L - 1)
        return k1, k1 + 1, L - k1
    a = cfg.attn
    if a is not None and a.global_every:
        ge = a.global_every
        k1 = L % ge or ge
        return k1, k1 + ge, (L - k1) // ge
    return 1, 2, L - 1


def probe_config(cfg, k: int):
    enc = min(cfg.encoder_layers, k) if cfg.encoder_layers else 0
    return dataclasses.replace(
        cfg, num_layers=k, encoder_layers=enc, scan_layers=False,
        ft=dataclasses.replace(cfg.ft, scan_unroll=True))


def _sds(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def _repl(mesh):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def _batch_specs(cfg, shape, mesh):
    b, s = shape.global_batch, shape.seq_len
    bs = batch_sharding(mesh, 2)
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bs),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bs),
    }
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=batch_sharding(mesh, 3))
    return batch


def input_specs(arch_or_cfg, shape_name: str, mesh, *,
                inference_layout: bool = False):
    """ShapeDtypeStruct stand-ins for every input of this cell (no alloc)."""
    cfg = (arch_or_cfg if not isinstance(arch_or_cfg, str)
           else cell_config(arch_or_cfg, shape_name))
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    params_shape = jax.eval_shape(
        model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = param_shardings(params_shape, mesh, inference=inference_layout)
    params = _sds(params_shape, pshard)

    if shape.kind == "train":
        opt = AdamW(lr=warmup_cosine(3e-4),
                    state_dtype="bfloat16" if cfg.dtype == "bfloat16" else None)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_sds = type(opt_shape)(
            m=_sds(opt_shape.m, pshard), v=_sds(opt_shape.v, pshard),
            count=jax.ShapeDtypeStruct((), jnp.int32, sharding=_repl(mesh)))
        state = TrainState(
            params=params, opt=opt_sds,
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=_repl(mesh)),
            ef=None)
        return {"state": state, "batch": _batch_specs(cfg, shape, mesh),
                "opt": opt, "model": model, "cfg": cfg}

    b = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(b, cache_len=shape.seq_len))
    cache = _sds(cache_shape, cache_shardings(cache_shape, mesh, batch=b))
    bs = batch_sharding(mesh, 2) if b >= 8 else _repl(mesh)
    tok_len = shape.seq_len if shape.kind == "prefill" else 1
    tokens = jax.ShapeDtypeStruct((b, tok_len), jnp.int32, sharding=bs)
    extra = {}
    if cfg.family in ("vlm", "audio"):
        extra["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=batch_sharding(mesh, 3) if b >= 8 else _repl(mesh))
    return {"params": params, "cache": cache, "tokens": tokens,
            "extra": extra, "model": model, "cfg": cfg}


def model_flops_estimate(cfg, shape) -> float:
    n_active = cfg.active_param_count_estimate()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n_active * tokens


def _compile_cell(cfg, shape_name, mesh, *, inference_layout=False,
                  microbatches=1):
    """Lower + compile one variant. Returns (compiled, lower_s, compile_s)."""
    shape = SHAPES[shape_name]
    spec = input_specs(cfg, shape_name, mesh,
                       inference_layout=inference_layout)
    model = spec["model"]
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = make_train_step(model, spec["opt"], mesh=mesh,
                                   microbatches=microbatches)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                spec["state"], spec["batch"])
        elif shape.kind == "prefill":
            def prefill(params, tokens, cache, extra):
                return model.prefill(params, tokens, cache, mesh=mesh, **extra)
            lowered = jax.jit(prefill, donate_argnums=(2,)).lower(
                spec["params"], spec["tokens"], spec["cache"], spec["extra"])
        else:
            def decode(params, token, cache):
                return model.decode_step(params, token, cache, mesh=mesh)
            lowered = jax.jit(decode, donate_argnums=(2,)).lower(
                spec["params"], spec["tokens"], spec["cache"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0 - t_lower


def _costs(compiled):
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(txt),
        "ops": count_ops(txt),
    }


def _extrapolate(c1, c2, n):
    def lin(a, b):
        return a + n * (b - a)
    kinds = set(c1["coll"]) | set(c2["coll"])
    coll = {k: max(0.0, lin(c1["coll"].get(k, 0), c2["coll"].get(k, 0)))
            for k in kinds}
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "coll": coll,
    }


def run_cell(arch, shape_name, *, multi_pod, out_dir, probes=True,
             cfg_override=None, tag="", inference_layout=False,
             microbatches=1):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = cfg_override or cell_config(arch, shape_name)
    kw = dict(inference_layout=inference_layout, microbatches=microbatches)

    # 1) certification compile: full depth, scanned
    compiled, t_lower, t_compile = _compile_cell(cfg, shape_name, mesh, **kw)
    mem = compiled.memory_analysis()
    peak = ((getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0))

    # 2) probe compiles: layer-count extrapolation for roofline terms
    if probes:
        k1, k2, n_per = probe_plan(cfg)
        p1 = _costs(_compile_cell(probe_config(cfg, k1), shape_name, mesh,
                                  **kw)[0])
        p2 = _costs(_compile_cell(probe_config(cfg, k2), shape_name, mesh,
                                  **kw)[0])
        total = _extrapolate(p1, p2, n_per)
        if microbatches > 1:
            # the microbatch accumulation scan is a while loop too — its body
            # is counted once by cost_analysis; scale to the real step.
            total["flops"] *= microbatches
            total["bytes"] *= microbatches
            total["coll"] = {k: v * microbatches
                             for k, v in total["coll"].items()}
    else:
        total = _costs(compiled)
        k1 = k2 = n_per = -1

    n_dev = mesh.devices.size
    flops_dev = total["flops"]
    bytes_dev = total["bytes"]
    coll_total = float(sum(total["coll"].values()))
    mf = model_flops_estimate(cfg, shape)

    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "kind": shape.kind, "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "probe_plan": [k1, k2, n_per],
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": total["coll"],
        "collective_total_per_device": coll_total,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": peak,
            "fits_16gb": bool(peak <= 16e9),
        },
        "model_flops": mf,
        "roofline": terms,
        "dominant": max(terms, key=terms.get),
        "useful_flops_ratio": (mf / (flops_dev * n_dev) if flops_dev else None),
        "roofline_fraction": (
            mf / PEAK_FLOPS / n_dev / max(terms.values())
            if max(terms.values()) > 0 else None),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "__" + tag if tag else ""
    name = "{}__{}__{}{}.json".format(arch, shape_name, result["mesh"], suffix)
    (out_dir / name).write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                ok, why = cell_applicable(arch, shape)
                if not ok:
                    print("SKIP {} x {}: {}".format(arch, shape, why),
                          flush=True)
                    continue
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        path = out_dir / "{}__{}__{}.json".format(arch, shape, mesh_name)
        if path.exists() and not args.force:
            print("CACHED {} x {} x {}".format(arch, shape, mesh_name),
                  flush=True)
            continue
        try:
            t0 = time.time()
            r = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                         probes=not args.no_probes)
            print("OK {} x {} x {}: dom={} c={:.2e} m={:.2e} x={:.2e} "
                  "peak={:.2f}GB rf={} [{:.0f}s]".format(
                      arch, shape, mesh_name, r["dominant"][:-2],
                      r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                      r["roofline"]["collective_s"],
                      r["memory"]["peak_bytes"] / 1e9,
                      r["roofline_fraction"] and round(r["roofline_fraction"], 3),
                      time.time() - t0), flush=True)
        except Exception as e:
            failures += 1
            print("FAIL {} x {} x {}: {}: {}".format(
                arch, shape, mesh_name, type(e).__name__, str(e)[:300]),
                flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
