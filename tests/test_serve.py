"""Serving: prefill + decode must exactly match the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

REPRESENTATIVE = ["gpt2", "gemma3-1b", "hymba-1.5b", "rwkv6-7b",
                  "whisper-base", "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", REPRESENTATIVE)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 3), 0,
                                cfg.vocab_size)
    kw, batch = {}, {"tokens": tokens}
    if cfg.family in ("vlm", "audio"):
        fe = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.frontend_tokens, cfg.d_model)) * 0.1
        kw["frontend"] = fe
        batch["frontend"] = fe
    if cfg.family == "encdec":
        et = jnp.ones((B, 8), jnp.int32)
        kw["enc_tokens"] = et
        batch["enc_tokens"] = et
    full, _ = model.logits(params, batch)
    cache = model.init_cache(B, cache_len=S + 8)
    lg, _, cache = model.prefill(params, tokens[:, :S], cache, **kw)
    np.testing.assert_allclose(lg, full[:, S - 1], atol=2e-5)
    for t in range(3):
        lg, _, cache = model.decode_step(params, tokens[:, S + t:S + t + 1],
                                         cache)
        np.testing.assert_allclose(lg, full[:, S + t], atol=2e-5)


def test_greedy_generate_with_fault_report():
    from repro.serve import greedy_generate
    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.ones((2, 8), jnp.int32)
    out, rep = greedy_generate(model, params, tokens, steps=4)
    assert out.shape == (2, 4)
    assert int(rep.detected.sum()) == 0
