"""Straggler and fault monitoring for the training loop.

On a real pod this wraps per-host heartbeats; the detection logic (which is
what we can exercise here) is host-agnostic: robust step-time outliers via
median + MAD, plus an EFTA fault-rate monitor that escalates when the
attention layer reports a sustained detection rate (a symptom of a failing
chip rather than transient SEUs — the launcher should then cordon the host
and trigger an elastic restart from the last checkpoint).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    step_time: float
    median: float
    threshold: float


class StragglerMonitor:
    """Flags steps slower than median + k*MAD over a sliding window."""

    def __init__(self, window: int = 50, k: float = 6.0, warmup: int = 5):
        self.times: Deque[float] = collections.deque(maxlen=window)
        self.k = k
        self.warmup = warmup
        self._t0: Optional[float] = None
        self.flagged = 0

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self) -> StragglerVerdict:
        dt = time.perf_counter() - self._t0
        verdict = self.observe(dt)
        return verdict

    def observe(self, dt: float) -> StragglerVerdict:
        if len(self.times) < self.warmup:
            self.times.append(dt)
            return StragglerVerdict(False, dt, dt, float("inf"))
        ts = sorted(self.times)
        med = ts[len(ts) // 2]
        mad = sorted(abs(t - med) for t in ts)[len(ts) // 2]
        thr = med + self.k * max(mad, 0.05 * med)
        is_slow = dt > thr
        self.times.append(dt)
        if is_slow:
            self.flagged += 1
        return StragglerVerdict(is_slow, dt, med, thr)


class FaultRateMonitor:
    """Escalates when EFTA detections persist (suspect bad hardware)."""

    def __init__(self, window: int = 100, sustained_threshold: float = 0.2):
        self.history: Deque[int] = collections.deque(maxlen=window)
        self.sustained_threshold = sustained_threshold

    def observe(self, detected_this_step: int) -> str:
        self.history.append(int(detected_this_step))
        if not self.history:
            return "ok"
        rate = sum(1 for d in self.history if d > 0) / len(self.history)
        if len(self.history) >= 20 and rate >= self.sustained_threshold:
            return "cordon"      # sustained faults: cordon host, elastic restart
        if detected_this_step > 0:
            return "corrected"   # transient SEU handled in-kernel by EFTA
        return "ok"
