"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (AttnCfg, FTCfg, ModelConfig, MoECfg, SSMCfg,
                                reduced)
from repro.configs.shapes import SHAPES, ShapeCfg, cell_applicable

_MODULES = {
    # assigned pool (10)
    "arctic-480b": "arctic_480b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-12b": "stablelm_12b",
    "gemma3-1b": "gemma3_1b",
    "rwkv6-7b": "rwkv6_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-base": "whisper_base",
    # paper's own models (Table 3)
    "gpt2": "gpt2",
    "bert-base": "bert_base",
    "bert-large": "bert_large",
    "t5-small": "t5_small",
}

ASSIGNED_ARCHS = list(_MODULES)[:10]
PAPER_ARCHS = list(_MODULES)[10:]


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(_MODULES)
