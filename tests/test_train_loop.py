"""Integration: loss decreases on the synthetic pipeline; microbatching
equivalence; FT telemetry surfaces in metrics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_pipeline
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import init_state, make_train_step
import pytest

pytestmark = pytest.mark.quick


def run(steps=80, microbatches=1, seed=0):
    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    opt = AdamW(lr=warmup_cosine(8e-3, warmup=5, total=steps))
    state = init_state(model, opt, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(model, opt, microbatches=microbatches))
    data = make_pipeline(cfg, global_batch=8, seq_len=32, seed=seed)
    eval_batch = {k: jnp.asarray(v) for k, v in data.batch(10_000).items()}
    eval_fn = jax.jit(lambda p: model.loss(p, eval_batch)[0])
    before = float(eval_fn(state.params))
    losses, metrics = [], None
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    after = float(eval_fn(state.params))
    return before, after, losses, metrics


def test_loss_decreases():
    before, after, losses, metrics = run()
    assert after < before * 0.92, (before, after)
    assert "ft_detected" in metrics


def test_microbatch_accumulation_close_to_full_batch():
    *_, l1, _ = run(steps=6, microbatches=1, seed=3)
    *_, l2, _ = run(steps=6, microbatches=2, seed=3)
    # same data, averaged grads -> trajectories should be close
    np.testing.assert_allclose(l1, l2, rtol=0.05, atol=0.05)
