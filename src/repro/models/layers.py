"""Shared neural-net layers (pure functional: init fns return pytrees,
apply fns are stateless)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.abft_gemm import tensor_abft_matmul


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / (d_in ** 0.5))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def matmul(x, w, *, ff_abft: bool = False):
    """Linear projection; optionally protected by tensor-checksum ABFT."""
    if ff_abft:
        y, _ = tensor_abft_matmul(x, w)
        return y
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm_init(d: int, dtype):
    return {"w": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["w"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["w"].astype(jnp.float32)
            + params["b"].astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def mlp_init(key, d: int, ff: int, dtype, *, glu: bool):
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], ff, d, dtype)}
    if glu:
        p["gate"] = dense_init(ks[0], d, ff, dtype)
        p["up"] = dense_init(ks[1], d, ff, dtype)
    else:
        p["up"] = dense_init(ks[1], d, ff, dtype)
    return p


def mlp_apply(params, x, *, act: str, glu: bool, ff_abft: bool = False):
    a = ACTS[act]
    if glu:
        h = a(matmul(x, params["gate"], ff_abft=ff_abft)) * matmul(
            x, params["up"], ff_abft=ff_abft)
    else:
        h = a(matmul(x, params["up"], ff_abft=ff_abft))
    return matmul(h, params["down"], ff_abft=ff_abft)


def embed_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, *, table=None):
    t = table if table is not None else params["table"]
    return jnp.matmul(x, t.T.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions: (..., S).
    ``theta`` may be a traced scalar (per-layer rope base)."""
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq    # (..., S, half)
    if x.ndim == ang.ndim + 2:                               # head dim present
        ang = ang[..., None, :]                              # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def learned_pos_init(key, max_seq: int, d: int, dtype):
    return {"pos": (jax.random.normal(key, (max_seq, d), jnp.float32)
                    * 0.02).astype(dtype)}
