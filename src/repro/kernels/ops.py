"""Jit'd dispatch for attention implementations.

``attention(...)`` routes between:
  * ``efta_pallas`` — the fused Pallas TPU kernel (interpret=True on CPU)
  * ``efta``        — pure-JAX EFTA (jit/pjit/differentiable; used at scale)
  * ``flash``       — pure-JAX flash attention, fault tolerance off
  * ``reference``   — naive O(n²) softmax attention
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.efta import EFTAConfig, FTReport, efta_attention, reference_attention
from repro.kernels.efta_attention import efta_attention_pallas

IMPLS = ("efta_pallas", "efta", "flash", "reference")


def attention(
    q, k, v, *,
    impl: str = "efta",
    cfg: Optional[EFTAConfig] = None,
    causal: bool = False,
    window: Optional[int] = None,
    kv_len=None,
    q_offset=0,
    sm_scale: Optional[float] = None,
    fault=None,
    kv_positions=None,
    interpret: bool = True,
):
    """Unified attention entry point. Returns (out, FTReport)."""
    cfg = cfg or EFTAConfig()
    if impl == "reference":
        out = reference_attention(q, k, v, causal=causal, window=window,
                                  kv_len=kv_len, q_offset=q_offset,
                                  sm_scale=sm_scale, kv_positions=kv_positions)
        return out, FTReport.zero()
    if impl == "flash":
        off = EFTAConfig(mode="off", stride=cfg.stride, block_kv=cfg.block_kv)
        return efta_attention(q, k, v, cfg=off, causal=causal, window=window,
                              kv_len=kv_len, q_offset=q_offset,
                              sm_scale=sm_scale, kv_positions=kv_positions)
    if impl == "efta":
        return efta_attention(q, k, v, cfg=cfg, causal=causal, window=window,
                              kv_len=kv_len, q_offset=q_offset,
                              sm_scale=sm_scale, fault=fault,
                              kv_positions=kv_positions)
    if impl == "efta_pallas":
        if kv_positions is not None or q_offset != 0 or (
                kv_len is not None
                and not isinstance(kv_len, (int, np.integer))):
            raise NotImplementedError(
                "ring caches / decode offsets / traced kv_len route through "
                "impl='efta'; the Pallas kernel takes a static ragged kv_len")
        out, det = efta_attention_pallas(
            q, k, v, cfg=cfg, causal=causal, window=window,
            kv_len=None if kv_len is None else int(kv_len),
            sm_scale=sm_scale, fault=fault, interpret=interpret)
        return out, FTReport(det, det if cfg.mode == "correct" else det * 0,
                             jnp.zeros((3,), jnp.float32))
    raise ValueError(f"unknown attention impl {impl!r}; one of {IMPLS}")


# --- paged KV-cache paths --------------------------------------------------
#
# The paged serve engine stores KV in a global block pool
# ``(num_layers, num_blocks, Hkv, block_size, head_dim)`` and addresses it
# through per-request block tables. Two decode backends consume it:
#
#   * gather (below): materialize each request's table as the contiguous
#     ``(.., Hkv, S, hd)`` layout every impl above already accepts (token
#     position == table order), so EFTA / flash / reference all serve paged
#     caches for free — at the cost of an extra HBM round-trip per byte and
#     a separate full-pool checksum pass. This is the portable baseline;
#     its prefill / prefix-extend / block-repair run through one
#     fixed-width chunked ``Model.extend`` program.
#   * fused (``repro.kernels.efta_paged.efta_paged_attention_pallas``):
#     unified multi-token Pallas kernel whose BlockSpec index maps read the
#     block table directly (scalar prefetch), with the batch axis in the
#     grid (native batched ragged chunks: per-request ``kv_len`` AND
#     ``q_len`` masking serve mixed prefill/extend/repair/decode batches in
#     one program) and the resident block-checksum verify folded into the
#     KV streaming loop. Dispatched via ``PagedServeEngine(kernel="fused")``
#     through ``repro.models.attention.PagedKVCache``.


def merge_block_axes(x: jax.Array) -> jax.Array:
    """(L, ..., mb, Hkv, bs, hd) gathered blocks -> (L, ..., Hkv, mb*bs, hd)
    contiguous KV layout (table order becomes token order)."""
    n = x.ndim
    x = x.transpose(*range(n - 4), n - 3, n - 4, n - 2, n - 1)
    return x.reshape(*x.shape[:-3], x.shape[-3] * x.shape[-2], x.shape[-1])


def gather_block_kv(pool: jax.Array,
                    block_table: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather a paged pool array by block table.

    ``pool``: (L, num_blocks, Hkv, bs, hd); ``block_table``: int32 block ids
    of shape (mb,) or (n_slots, mb), null-padded with block 0. Returns both
    views of the single gather: the raw block layout
    ``(L[, n_slots], mb, Hkv, bs, hd)`` (what read-time checksum
    verification folds over) and the contiguous per-request KV view
    ``(L[, n_slots], Hkv, mb*bs, hd)`` (what attention consumes).
    """
    raw = pool[:, block_table]
    return raw, merge_block_axes(raw)


@functools.partial(jax.jit, static_argnames=("impl", "cfg", "causal", "window",
                                             "sm_scale", "interpret"))
def attention_jit(q, k, v, *, impl="efta", cfg=None, causal=False, window=None,
                  sm_scale=None, interpret=True):
    return attention(q, k, v, impl=impl, cfg=cfg, causal=causal, window=window,
                     sm_scale=sm_scale, interpret=interpret)
