from repro.optim.adamw import AdamW, AdamWState, warmup_cosine
