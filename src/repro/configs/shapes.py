"""The assigned input-shape suite (4 shapes x 10 archs = 40 cells).

``long_500k`` lowers ``serve_step`` with a 524288-token KV context and needs
sub-quadratic attention: it runs for ssm/hybrid/sliding-window archs and is
skipped (with the reason recorded) for pure full-attention archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# Archs with sub-quadratic sequence handling (SSM state, hybrid, or
# sliding-window-dominated attention) run long_500k; pure full-attention
# archs skip it (recorded in DESIGN.md §Arch-applicability).
SUBQUADRATIC = {"rwkv6-7b", "hymba-1.5b", "gemma3-1b"}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention (per assignment note)"
    return True, ""
