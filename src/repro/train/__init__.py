from repro.train.state import TrainState
from repro.train.step import init_state, make_train_step
