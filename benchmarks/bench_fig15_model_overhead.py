"""Paper Fig. 15 / Table 3: EFTA detection + correction overhead on the
paper's models (GPT-2, BERT-Base, BERT-Large, T5-Small), inference step.

Reduced widths run on the CPU host; the overhead is relative (paper metric).
One trial injects a real fault so the correction path executes."""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.models import build_model

MODELS = ["gpt2", "bert-base", "bert-large", "t5-small"]


def run():
    rows = []
    for name in MODELS:
        cfg = get_config(name + "-smoke")
        batch = {"tokens": jnp.ones((2, 64), jnp.int32)}
        if cfg.family == "encdec":
            batch["enc_tokens"] = jnp.ones((2, 32), jnp.int32)
        times = {}
        for mode in ("off", "detect", "correct"):
            c = dataclasses.replace(
                cfg, ft=dataclasses.replace(cfg.ft, mode=mode))
            model = build_model(c)
            params = model.init(jax.random.PRNGKey(0))
            fn = jax.jit(lambda p, b: model.logits(p, b)[0])
            times[mode] = time_fn(fn, params, batch)
        base = times["off"]
        rows.append({"name": f"{name}_detect", "us": times["detect"] * 1e6,
                     "derived": f"oh={(times['detect']-base)/base*100:.1f}%"})
        rows.append({"name": f"{name}_correct", "us": times["correct"] * 1e6,
                     "derived": f"oh={(times['correct']-base)/base*100:.1f}%"})
    emit(rows, "Fig15/Table3: model-level EFTA overhead")
    return rows


if __name__ == "__main__":
    run()
