"""t5-small (paper Table 3): 18 attention layers = 6 enc self + 6 dec self +
6 dec cross; 8H head_dim=64. Uses RoPE in this repo (relative-bias deviation
noted in DESIGN.md)."""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="t5-small", family="encdec",
    num_layers=6, encoder_layers=6, d_model=512, d_ff=2048, vocab_size=32128,
    attn=AttnCfg(num_heads=8, num_kv_heads=8, head_dim=64),
    glu=False, act="relu", max_seq=512,
    source="paper Table 3",
)
