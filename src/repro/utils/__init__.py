from repro.utils.tree import param_count, param_bytes, tree_flatten_with_names
from repro.utils.logging import get_logger
