"""FT runtime: checkpoint roundtrip + reshard, straggler + fault monitors,
elastic re-mesh planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft_runtime import (AsyncCheckpointer, FaultRateMonitor,
                              MeshPlan, StragglerMonitor, latest_step,
                              plan_mesh, restore, save)

pytestmark = pytest.mark.quick


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save(tmp_path / "step_5", tree, step=5, extra={"note": "x"})
    out, step, extra = restore(tmp_path / "step_5", tree)
    assert step == 5 and extra["note"] == "x"
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_checkpoint_atomic_and_latest(tmp_path):
    t1 = {"a": jnp.zeros((2,))}
    save(tmp_path / "step_1", t1, step=1)
    save(tmp_path / "step_3", t1, step=3)
    assert latest_step(tmp_path).name == "step_3"


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer()
    tree = {"w": jnp.ones((128, 128))}
    ck.save_async(tmp_path / "step_2", tree, step=2)
    ck.wait()
    out, step, _ = restore(tmp_path / "step_2", tree)
    assert step == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(tmp_path / "s", {"a": jnp.zeros((2,))}, step=0)
    with pytest.raises(ValueError):
        restore(tmp_path / "s", {"a": jnp.zeros((3,))})


def test_straggler_monitor():
    m = StragglerMonitor(window=20, k=6.0, warmup=5)
    for _ in range(10):
        m.observe(0.1)
    v = m.observe(5.0)
    assert v.is_straggler
    v2 = m.observe(0.11)
    assert not v2.is_straggler


def test_fault_rate_monitor_escalates():
    f = FaultRateMonitor(window=30, sustained_threshold=0.2)
    assert f.observe(0) == "ok"
    assert f.observe(1) == "corrected"
    for _ in range(25):
        f.observe(1)
    assert f.observe(1) == "cordon"


def test_elastic_plan():
    p = plan_mesh(512, model_parallel=16)
    assert p.shape == (2, 16, 16)
    p2 = plan_mesh(240, model_parallel=16)   # one host lost from a 256 pod
    assert p2.shape == (15, 16) and p2.dropped_devices == 0
    assert plan_mesh(8, model_parallel=16) is None
