"""Serving step factories: prefill and decode, with context-parallel decode
for long contexts (flash-decoding over the ``data`` axis — the EFTA running
(m, l) rescale algebra is exactly the partial-softmax combine needed, so
fault-tolerant attention composes with CP for free)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model


def make_prefill_step(model: Model, *, mesh=None):
    def prefill(params, tokens, cache, frontend=None, enc_tokens=None):
        return model.prefill(params, tokens, cache, frontend=frontend,
                             enc_tokens=enc_tokens, mesh=mesh)
    return prefill


def make_decode_step(model: Model, *, mesh=None):
    def decode(params, token, cache):
        return model.decode_step(params, token, cache, mesh=mesh)
    return decode


def greedy_generate(model: Model, params, tokens, *, steps: int,
                    cache_len: Optional[int] = None, mesh=None, **prefill_kw):
    """Per-token Python-loop greedy decoder.

    Kept as the exactness oracle and throughput baseline for the
    continuous-batching ``repro.serve.engine.ServeEngine`` (which must be
    token-identical to running this per request); production serving goes
    through the engine."""
    b = tokens.shape[0]
    cache = model.init_cache(b, cache_len=cache_len or
                             (tokens.shape[1] + steps + 1))
    logits, rep, cache = model.prefill(params, tokens, cache, mesh=mesh,
                                       **prefill_kw)
    out = []
    reports = [rep]
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for _ in range(steps):
        out.append(tok)
        logits, rep, cache = model.decode_step(params, tok, cache, mesh=mesh)
        reports.append(rep)
        tok = jnp.argmax(logits, axis=-1)[:, None]
    rep_total = functools.reduce(lambda a, b: a.merge(b), reports)
    return jnp.concatenate(out, axis=1), rep_total
