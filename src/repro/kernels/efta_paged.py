"""Fused block-table EFTA paged-attention kernel (unified multi-token path).

The paged serve engine's PR-2 decode gathered each request's block table into
a contiguous KV view *outside* the kernel, then vmapped the pure-JAX EFTA
path over slots — one extra HBM round-trip for every byte of KV, plus a
separate full-pool checksum pass. This kernel removes both:

  * **Block tables are consumed directly by BlockSpec index maps**: the grid
    is ``(batch, kv_heads, table_len)`` and the K/V (and checksum) tiles for
    step ``(b, h, j)`` are fetched from pool row ``block_table[b, j]`` via
    scalar-prefetch index maps — the contiguous view is never materialized.
  * **Native batched ragged decode**: the batch axis is a grid dimension, so
    one kernel launch decodes every slot; each request masks its own
    ``kv_len`` (valid-token count from its block table) and blocks past the
    valid prefix are skipped entirely.
  * **Read-time block verification rides the streaming loop**: the resident
    block checksums (``repro.core.checksum.encode_kv``, written at append /
    scatter time) stream through the same index map as the data, and the
    fold is recomputed and compared *in the pass that consumes the block* —
    site 6 (``kv``) of the report tile, plus a per-(request, table-slot)
    ``bad`` plane the engine's repair path consumes. A resident HBM bit flip
    therefore costs zero extra memory traffic to detect.

Since PR 4 the q block is **multi-token**: each request brings a chunk of up
to ``C`` query rows (``q`` of shape ``(B, H, C, D)``) with a per-request
valid-row count ``q_lens``, so *one* compiled program covers single-token
decode (``C = 1`` or ``q_len = 1``), chunked prefill, prefix-extend, and
block repair — the unified end-to-end protected kernel the paper argues for,
replacing the per-bucket prefill programs of the gather path. Chunk row
``c`` sits at absolute position ``kv_len - q_len + c``; masking is causal
within the chunk, sliding-window, and ragged per request, all per *row*.
Rows past ``q_len`` are padding: fully masked, they emit zero output and
cannot trip any verification (every check compares self-consistent computed
values). A chunk may straddle block edges; the KV rows the chunk itself
appends are scattered (and their block checksums regenerated) by the caller
*before* the launch (``repro.models.attention._paged_chunk``), so the
streaming verify covers the chunk's own blocks too.

GQA is handled by folding the query-head group — and now the chunk axis —
into the GEMM rows: the score tile for one (request, kv-head) step is
``(group * C, block_size)``, so MQA/GQA ratios and chunk widths change tile
shapes, not code paths. The EFTA scheme itself (tensor-checksum ABFT on
GEMM I, checksum-reuse EXP verify, shadow rowmax, SNVR + shadow rowsum,
unified output verification — paper Algorithm 1) is inherited unchanged from
``repro.kernels.efta_attention``; this kernel reuses its fold and correction
helpers so the two stay in lockstep.

Fault descriptor (int32[8]): [site, table_block j, batch b, kv-head h,
tile-row (group_row * C + chunk_row), col, bit, enabled] — one SEU per step,
matching the paper's single-event model. ``Site.KV`` faults are *not*
injected here: they strike the resident pool between steps
(``PagedServeEngine.inject_kv_fault``) and this kernel's job is to catch
them.

Validated in interpret mode on CPU; lowers for TPU via Mosaic (on real TPUs
pick ``head_dim``/``block_size`` multiples of the (8, 128) f32 tile and a
``group * C`` row count that is a multiple of 8).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import checksum as cks
from repro.core.efta import EFTAConfig, MASK_VALUE
from repro.core.fault import Site
from repro.kernels.efta_attention import (_CompilerParams, _correct_strided,
                                          _flip, _fold_prod, _fold_slices)

# fault descriptor layout (int32[8]):
# [site, table_block, batch, kv_head, tile_row, col, bit, enabled]
P_SITE, P_BLOCK, P_B, P_H, P_ROW, P_COL, P_BIT, P_ON = range(8)

NO_WINDOW = 1 << 30     # "global attention" sentinel for the window scalar


class PagedReport(NamedTuple):
    """Per-request outcome of one fused paged-attention call."""

    out: jax.Array        # (B, H, head_dim) or (B, H, C, head_dim) output
    detected: jax.Array   # (B, 6) int32 — [gemm1, exp, rowmax, rowsum,
    #                       gemm2, kv] per request, summed over kv heads
    bad_blocks: jax.Array  # (B, table_len) bool — resident-checksum
    #                        mismatches, addressed by table slot (not pool id)


def _hit(fault_ref, site, *, b, h, j):
    return ((fault_ref[P_ON] == 1)
            & (fault_ref[P_SITE] == int(site))
            & (fault_ref[P_B] == b)
            & (fault_ref[P_H] == h)
            & (fault_ref[P_BLOCK] == j))


def _paged_kernel(
    # scalar prefetch
    fault_ref, bt_ref, kvlen_ref, qlen_ref, win_ref,
    # inputs
    q_ref, k_ref, v_ref, kc1_ref, kc2_ref, vc1_ref, vc2_ref,
    # outputs
    o_ref, rep_ref, bad_ref,
    # scratch
    m_scr, l_scr, lsh_scr, r_scr, acc_scr, oc1_scr, oc2_scr, det_scr,
    vmax_scr,
    *,
    sm_scale: float,
    block_size: int,
    n_blocks: int,
    chunk: int,
    s_kv: int,
    s_out: int,
    kv_thr: float,
    mode: str,
    unified: bool,
    shadow_rowsum: bool,
    shadow_rowmax: bool,
    eps1: float,
    eps2: float,
    eps3: float,
):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    ft = mode != "off"
    correct = mode == "correct"
    bs = block_size
    g_kv = bs // s_kv

    kv_len = kvlen_ref[b]       # valid tokens incl. the chunk's rows (traced)
    q_len = qlen_ref[b]         # valid chunk rows for this request (traced)
    window = win_ref[0]
    base = kv_len - q_len       # absolute position of chunk row 0

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        lsh_scr[...] = jnp.zeros_like(lsh_scr)
        r_scr[...] = jnp.zeros_like(r_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        oc1_scr[...] = jnp.zeros_like(oc1_scr)
        oc2_scr[...] = jnp.zeros_like(oc2_scr)
        for i in range(6):
            det_scr[i] = 0
        vmax_scr[0] = 0.0
        bad_ref[...] = jnp.zeros_like(bad_ref)

    # Ragged / causal skip: blocks entirely past every chunk row's valid
    # prefix (or entirely outside every row's sliding window — the earliest
    # row ``base`` has the lowest window floor) contribute nothing — no MXU
    # work, no checksum folds. Null-padded table entries point at pool row 0
    # and always land here or under the verify's ``real`` gate.
    kv_start = j * bs
    run = (kv_start < kv_len) & (base - (kv_start + bs - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[...]                  # (grp * C, D), rows group-major
        k = k_ref[...]                  # (bs, D)
        v = v_ref[...]                  # (bs, D)
        real = bt_ref[b, j] > 0

        if ft:
            # ---- site 6 (kv): resident block verify, in the streaming ----
            # pass that consumes the block. Fold definition and threshold
            # semantics are shared with the gather path via core.checksum,
            # so both backends flag exactly the same corruptions.
            cs = kc1_ref.shape[0]
            fk = cks.encode_kv_tile(k, cs)
            fv = cks.encode_kv_tile(v, cs)
            bad_k = cks.block_fold_bad(
                fk, cks.Checksums(kc1_ref[...], kc2_ref[...]), threshold=kv_thr)
            bad_v = cks.block_fold_bad(
                fv, cks.Checksums(vc1_ref[...], vc2_ref[...]), threshold=kv_thr)
            flag = (bad_k | bad_v) & real
            det_scr[5] += flag.astype(jnp.int32)
            onehot = jax.lax.broadcasted_iota(
                jnp.int32, bad_ref.shape, 1) == j
            bad_ref[...] = jnp.maximum(
                bad_ref[...], (onehot & flag).astype(jnp.int32))

            # running max|V|: the convex-combination bound for finalize NVR
            vmax_scr[0] = jnp.maximum(
                vmax_scr[0], jnp.max(jnp.abs(v.astype(jnp.float32))))

        # ---- GEMM I on the MXU (f32 accumulate) + tensor-checksum ABFT ----
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (grp * C, bs)
        s = _flip(s, on=_hit(fault_ref, Site.GEMM1, b=b, h=h, j=j),
                  row=fault_ref[P_ROW], col=fault_ref[P_COL],
                  bit=fault_ref[P_BIT])
        if ft:
            # NVR range restriction (see efta_attention): keeps the weighted
            # fold finite under exponent-bit corruptions.
            s = jnp.where(jnp.isfinite(s), jnp.clip(s, -1e6, 1e6), 0.0)

        if ft:
            # CCG: tensor checksums of K (same strided row fold as the
            # resident verify above, at the ABFT stride), then skinny GEMMs
            kc1, kc2 = cks.encode_kv_tile(k, s_kv)
            sc1 = jax.lax.dot_general(
                q.astype(jnp.float32), kc1, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * sm_scale
            sc2 = jax.lax.dot_general(
                q.astype(jnp.float32), kc2, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            sc2 = sc2 * sm_scale
            sum1 = _fold_slices(s, s_kv, weighted=False)
            sum2 = _fold_slices(s, s_kv, weighted=True)
            d1 = sc1 - sum1
            d2 = sc2 - sum2
            bad = jnp.abs(d1) > eps1
            det_scr[0] += bad.sum(dtype=jnp.int32)
            if correct:
                s = _correct_strided(s, d1, d2, bad, s_kv)

        # ---- per-row causal + window + ragged mask, running max ----------
        # Tile rows are group-major: row r holds (group g = r // C, chunk
        # row c = r % C); chunk row c queries absolute position base + c.
        crow = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
        qpos = base + crow
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (cols <= qpos) & (qpos - cols < window) & (crow < q_len)
        s_m = jnp.where(mask, s, MASK_VALUE)
        blockmax = jnp.max(s_m, axis=1, keepdims=True)      # (grp * C, 1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, blockmax)
        m_new = _flip(m_new, on=_hit(fault_ref, Site.ROWMAX, b=b, h=h, j=j),
                      row=fault_ref[P_ROW], col=jnp.int32(0),
                      bit=fault_ref[P_BIT])
        if ft and shadow_rowmax:
            m_chk = jnp.maximum(jax.lax.optimization_barrier(m_prev), blockmax)
            bad_m = m_new != m_chk
            det_scr[2] += bad_m.sum(dtype=jnp.int32)
            if correct:
                m_new = jnp.where(bad_m, m_chk, m_new)
        m_scr[...] = m_new
        alive = m_new > MASK_VALUE / 2
        m_sub = jnp.where(alive, m_new, 0.0)

        # ---- EXP with checksum reuse (paper Case 2) ----------------------
        cap = 80.0 / g_kv
        p_raw = jnp.exp(jnp.minimum(s - m_sub, cap))
        p_raw = _flip(p_raw, on=_hit(fault_ref, Site.EXP, b=b, h=h, j=j),
                      row=fault_ref[P_ROW], col=fault_ref[P_COL],
                      bit=fault_ref[P_BIT])
        if ft:
            pc1 = jnp.exp(jnp.minimum(sc1 - g_kv * m_sub, cap * g_kv))
            prod = _fold_prod(p_raw, s_kv)
            ref = jnp.maximum(jnp.abs(pc1), 1e-20)
            bad_e = jnp.abs(prod - pc1) > eps2 * ref + 1e-20
            capped = (s - m_sub) > (cap - 1e-3)
            col_ok = jnp.ones((s.shape[0], s_kv), dtype=bool)
            for l in range(g_kv):
                col_ok &= ~capped[:, l * s_kv:(l + 1) * s_kv]
            bad_e &= col_ok
            det_scr[1] += bad_e.sum(dtype=jnp.int32)
            if correct:
                recomputed = jnp.exp(jnp.minimum(s - m_sub, cap))
                for l in range(g_kv):
                    seg = jnp.where(
                        bad_e, recomputed[:, l * s_kv:(l + 1) * s_kv],
                        p_raw[:, l * s_kv:(l + 1) * s_kv])
                    p_raw = jax.lax.dynamic_update_slice(
                        p_raw, seg, (0, l * s_kv))
        if ft and shadow_rowmax and correct:
            # exact recompute backstop (see efta_attention)
            recheck = jnp.exp(jnp.minimum(s - m_sub, cap))
            slipped = p_raw != recheck
            det_scr[1] += slipped.sum(dtype=jnp.int32)
            p_raw = jnp.where(slipped, recheck, p_raw)
        p = jnp.where(mask, p_raw, 0.0)

        # ---- rescale + rowsum (+ shadow) ---------------------------------
        alpha = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        l_new = _flip(l_new, on=_hit(fault_ref, Site.ROWSUM, b=b, h=h, j=j),
                      row=fault_ref[P_ROW], col=jnp.int32(0),
                      bit=fault_ref[P_BIT])
        l_scr[...] = l_new
        if ft and shadow_rowsum:
            p_sh = jax.lax.optimization_barrier(p)
            lsh_scr[...] = alpha * lsh_scr[...] + jnp.sum(p_sh, axis=1,
                                                          keepdims=True)
        blk_alive = blockmax > MASK_VALUE / 2
        r_scr[...] = alpha * r_scr[...] + jnp.where(
            blk_alive, jnp.exp(blockmax - m_sub), 0.0)

        # ---- GEMM II + rescale, checksums carried ------------------------
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (grp * C, D)
        acc_new = alpha * acc_scr[...] + pv
        acc_new = _flip(acc_new, on=_hit(fault_ref, Site.GEMM2, b=b, h=h, j=j),
                        row=fault_ref[P_ROW], col=fault_ref[P_COL],
                        bit=fault_ref[P_BIT])
        acc_scr[...] = acc_new
        if ft:
            g2 = v.shape[-1] // s_out
            vcs1 = jnp.zeros((v.shape[0], s_out), jnp.float32)
            vcs2 = jnp.zeros((v.shape[0], s_out), jnp.float32)
            for l in range(g2):
                seg = v[:, l * s_out:(l + 1) * s_out].astype(jnp.float32)
                vcs1 = vcs1 + seg
                vcs2 = vcs2 + float(l + 1) * seg
            pf = p.astype(jnp.float32)
            oc1_scr[...] = alpha * oc1_scr[...] + jax.lax.dot_general(
                pf, vcs1, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            oc2_scr[...] = alpha * oc2_scr[...] + jax.lax.dot_general(
                pf, vcs2, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            if not unified:
                s1 = _fold_slices(acc_scr[...], s_out, weighted=False)
                d1o = oc1_scr[...] - s1
                det_scr[4] += (jnp.abs(d1o) > eps3).sum(dtype=jnp.int32)

    # ---- finalize: SNVR on ℓ + unified output verification ----------------
    @pl.when(j == n_blocks - 1)
    def _finalize():
        l_f = l_scr[...]
        r_f = r_scr[...]
        if ft:
            # per-row SNVR bound: chunk row c attends at most qpos + 1 keys
            # (window-limited rows only tighten further; kv_len caps all)
            crow = jax.lax.broadcasted_iota(jnp.int32, l_f.shape, 0) % chunk
            upper = jnp.minimum(base + crow + 1, kv_len).astype(
                jnp.float32) + 1e-3
            in_range = (l_f >= r_f - 1e-3) & (l_f <= upper) & jnp.isfinite(l_f)
            if shadow_rowsum:
                lsh = lsh_scr[...]
                mism = jnp.abs(l_f - lsh) > 1e-5 * jnp.maximum(jnp.abs(lsh),
                                                               1e-6)
                bad_l = ((~in_range) | mism) & (r_f > 0)
                fb_ok = (lsh >= r_f - 1e-3) & (lsh <= upper) & jnp.isfinite(lsh)
                fallback = jnp.where(fb_ok, lsh, r_f)
            else:
                bad_l = (~in_range) & (r_f > 0)
                fallback = r_f
            det_scr[3] += bad_l.sum(dtype=jnp.int32)
            if correct:
                l_f = jnp.where(bad_l, fallback, l_f)
        l_safe = jnp.where(l_f == 0.0, 1.0, l_f)
        o = acc_scr[...] / l_safe
        if ft:
            if correct:
                bound = vmax_scr[0] * 1.001 + 1e-6
                o = jnp.where(jnp.isfinite(o) & (jnp.abs(o) <= bound),
                              o, 0.0)
            oc1 = oc1_scr[...] / l_safe
            oc2 = oc2_scr[...] / l_safe
            s1 = _fold_slices(o, s_out, weighted=False)
            s2 = _fold_slices(o, s_out, weighted=True)
            d1 = oc1 - s1
            d2 = oc2 - s2
            bad = ~(jnp.abs(d1) <= eps3)
            det_scr[4] += bad.sum(dtype=jnp.int32)
            if correct:
                o = _correct_strided(o, d1, d2, bad, s_out)
        o_ref[...] = o.astype(o_ref.dtype)
        for i in range(6):
            rep_ref[i] = det_scr[i]


def efta_paged_attention_pallas(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    k_checks: cks.Checksums,
    v_checks: cks.Checksums,
    block_tables: jax.Array,
    kv_lens: jax.Array,
    q_lens: Optional[jax.Array] = None,
    *,
    cfg: EFTAConfig,
    check_threshold: Optional[float] = None,
    window=None,
    sm_scale: Optional[float] = None,
    fault: Optional[jax.Array] = None,
    interpret: bool = True,
) -> PagedReport:
    """Fused batched ragged paged attention with in-loop verification.

    ``q``: (B, H, D) — single decode token per request — or (B, H, C, D) —
    a multi-token chunk per request (unified prefill / extend / repair /
    decode). ``k_pool``/``v_pool``: (num_blocks + 1, Hkv, block_size, D)
    paged pools (row 0 is the null block). ``k_checks``/``v_checks``: the
    resident :func:`repro.core.checksum.encode_kv` pairs, (num_blocks + 1,
    Hkv, check_stride, D). ``block_tables``: (B, table_len) int32,
    null-padded with 0. ``kv_lens``: (B,) int32 valid tokens per request
    *including* the chunk's rows (their K/V must already sit in the pool —
    append before attend, exactly like the gather path's in-step scatter).
    ``q_lens``: (B,) int32 valid rows of each request's chunk (default: all
    C); chunk row ``c < q_len`` queries position ``kv_len - q_len + c``,
    rows past ``q_len`` are fully-masked padding and a request with
    ``q_len == 0`` contributes nothing (its resident blocks are still
    streamed and verified).

    ``window``: optional sliding-window size — python int or traced int32
    scalar (per-layer global/local selection). ``fault``: optional int32[8]
    descriptor (see module docstring). Returns a :class:`PagedReport` whose
    ``out`` matches ``q``'s shape.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, :, None, :]
    b, h, chunk, d = q.shape
    nb1, hkv, bs, hd = k_pool.shape
    if hd != d:
        raise ValueError(f"head_dim mismatch: q {d} vs pool {hd}")
    grp = h // hkv
    mb = block_tables.shape[-1]
    cs = k_checks.c1.shape[-2]
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    s_kv = cfg.kv_stride(bs)
    s_out = cfg.out_stride(d)
    eps1, eps2, eps3 = cfg.thresholds(q.dtype)
    kv_thr = (check_threshold if check_threshold is not None
              else cks.kv_block_threshold(k_pool.dtype))

    # fold GQA group AND chunk into the GEMM rows, group-major: row
    # r = g * C + c so every per-row helper stays a plain lane-wise op
    qr = q.reshape(b, hkv, grp * chunk, d)
    if fault is None:
        fault = jnp.zeros((8,), jnp.int32)
    if q_lens is None:
        q_lens = jnp.full((b,), chunk, jnp.int32)
    win = (jnp.full((1,), NO_WINDOW, jnp.int32) if window is None
           else jnp.asarray(window, jnp.int32).reshape(1))

    kernel = functools.partial(
        _paged_kernel,
        sm_scale=scale, block_size=bs, n_blocks=mb, chunk=chunk, s_kv=s_kv,
        s_out=s_out, kv_thr=kv_thr, mode=cfg.mode, unified=cfg.unified,
        shadow_rowsum=cfg.shadow_rowsum, shadow_rowmax=cfg.shadow_rowmax,
        eps1=eps1, eps2=eps2, eps3=eps3)

    def pool_map(bi, hi, j, fault, bt, kvlen, qlen, win):
        return (bt[bi, j], hi, 0, 0)

    rows = grp * chunk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(b, hkv, mb),
        in_specs=[
            pl.BlockSpec((None, None, rows, d),
                         lambda bi, hi, j, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, bs, d), pool_map),
            pl.BlockSpec((None, None, bs, d), pool_map),
            pl.BlockSpec((None, None, cs, d), pool_map),
            pl.BlockSpec((None, None, cs, d), pool_map),
            pl.BlockSpec((None, None, cs, d), pool_map),
            pl.BlockSpec((None, None, cs, d), pool_map),
        ],
        out_specs=[
            pl.BlockSpec((None, None, rows, d),
                         lambda bi, hi, j, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, 6), lambda bi, hi, j, *_: (bi, hi, 0)),
            pl.BlockSpec((None, None, 1, mb),
                         lambda bi, hi, j, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # m
            pltpu.VMEM((rows, 1), jnp.float32),   # l
            pltpu.VMEM((rows, 1), jnp.float32),   # l shadow
            pltpu.VMEM((rows, 1), jnp.float32),   # r (SNVR bound)
            pltpu.VMEM((rows, d), jnp.float32),   # output accumulator
            pltpu.VMEM((rows, s_out), jnp.float32),  # O checksum 1
            pltpu.VMEM((rows, s_out), jnp.float32),  # O checksum 2
            pltpu.SMEM((6,), jnp.int32),          # detection counters
            pltpu.SMEM((1,), jnp.float32),        # running max|V| (NVR)
        ],
    )

    out, rep, bad = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
            jax.ShapeDtypeStruct((b, hkv, 6), jnp.int32),
            jax.ShapeDtypeStruct((b, hkv, 1, mb), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(fault, jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32), jnp.asarray(q_lens, jnp.int32), win,
      qr, k_pool, v_pool, k_checks.c1, k_checks.c2, v_checks.c1, v_checks.c2)

    out = out.reshape(b, h, chunk, d)
    return PagedReport(
        out=out[:, :, 0, :] if squeeze else out,
        detected=rep.sum(axis=1),
        bad_blocks=jnp.any(bad > 0, axis=(1, 2)))


def paged_fault_descriptor(spec, grp: int,
                           chunk: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Translate the serve engine's per-slot :class:`FaultSpec` batch into
    the fused kernel's int32[8] descriptor.

    ``spec`` fields are (n_slots, n_faults); the single-event-upset model
    means at most one entry is enabled per step, so the first enabled entry
    wins. The vmapped gather path addresses the score tile as (head, row);
    the fused kernel's tile rows fold the GQA group and the chunk axis, so
    the query-head coordinate splits into (kv_head = head // grp, tile row
    = (head % grp) * chunk) — the SEU strikes chunk row 0, which is a valid
    row for every request that fed at least one token this step.
    """
    site = spec.site.reshape(-1)
    nf = spec.site.shape[-1]
    enabled = site >= 0
    idx = jnp.argmax(enabled)
    on = jnp.any(enabled).astype(jnp.int32)

    def take(a):
        return a.reshape(-1)[idx]

    head = take(spec.head)
    return jnp.stack([
        take(spec.site), take(spec.block), (idx // nf).astype(jnp.int32),
        head // grp, (head % grp) * chunk, take(spec.col), take(spec.bit), on,
    ]).astype(jnp.int32)
