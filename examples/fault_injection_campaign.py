"""Model-level error-injection campaign (paper §5.3 style): random SEUs are
injected into attention of a small transformer during inference; we measure
silent-corruption rates with EFTA off/detect/correct.

The campaign machinery lives in ``repro.core.campaign`` and is shared with
the deterministic tier-1 test (``tests/test_fault_campaign.py``).

  PYTHONPATH=src python examples/fault_injection_campaign.py [n_trials]
"""
import sys

from repro.core import run_campaign

N = int(sys.argv[1]) if len(sys.argv) > 1 else 40

for mode in ("off", "detect", "correct"):
    result = run_campaign(mode=mode, n_trials=N, seed=1)
    print(result.format_table())
    print()
print("EFTA turns silent corruptions into detected (and corrected) events.")
