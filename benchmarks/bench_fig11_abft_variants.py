"""Paper Fig. 11: tensor-checksum ABFT vs traditional ABFT.

Measured on the attention GEMM shapes (QK^T and PV) and on feed-forward
GEMMs; also reports the *checksum-width* MXU overhead ratio that drives the
TPU design choice (DESIGN.md: s=128 'lane-aligned' port refuted)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import abft_matmul, tensor_abft_matmul


def run():
    rows = []
    rng = jax.random.PRNGKey(0)
    for (m, k, n) in [(512, 64, 512), (512, 512, 64), (1024, 256, 1024)]:
        x = jax.random.normal(rng, (m, k), jnp.float32)
        w = jax.random.normal(rng, (k, n), jnp.float32)
        t_raw = time_fn(jax.jit(lambda x, w: x @ w), x, w)
        t_trad = time_fn(jax.jit(lambda x, w: abft_matmul(x, w)[0]), x, w)
        for stride in (8, 128):
            t_tens = time_fn(jax.jit(
                lambda x, w, s=stride: tensor_abft_matmul(x, w, stride=s)[0]),
                x, w)
            s_eff = min(stride, max(n // 2, 4))
            rows.append({
                "name": f"tensor_s{stride}_{m}x{k}x{n}", "us": t_tens * 1e6,
                "derived": (f"oh={(t_tens-t_raw)/t_raw*100:.0f}%"
                            f";width_flops=+{2*s_eff/n*100:.0f}%")})
        rows.append({"name": f"traditional_{m}x{k}x{n}", "us": t_trad * 1e6,
                     "derived": f"oh={(t_trad-t_raw)/t_raw*100:.0f}%"})
        rows.append({"name": f"raw_{m}x{k}x{n}", "us": t_raw * 1e6,
                     "derived": "baseline"})
    emit(rows, "Fig11: tensor-checksum vs traditional ABFT")
    return rows


if __name__ == "__main__":
    run()
