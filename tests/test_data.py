"""Data pipeline: determinism + resume-by-step semantics."""
import numpy as np

from repro.data import DataConfig, SyntheticLM
import pytest

pytestmark = pytest.mark.quick


def test_deterministic_by_step():
    d1 = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))
    d2 = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(8)["tokens"], b1["tokens"])


def test_targets_are_shifted_tokens():
    d = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    b = d.batch(0)
    assert b["tokens"].shape == (2, 16) and b["targets"].shape == (2, 16)
    # learnable structure: repeats/progressions -> low entropy
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 64).all()


def test_frontend_stub():
    d = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=2,
                               frontend_tokens=5, d_model=16))
    b = d.batch(0)
    assert b["frontend"].shape == (2, 5, 16)
