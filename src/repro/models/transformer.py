"""Unified model assembly for every assigned architecture family.

One scanned-block stack covers dense / MoE / hybrid / SSM / VLM / enc-dec
variants. Heterogeneity is handled without breaking scan uniformity:

  * per-layer *flags* (gemma3 5:1 local:global windows, hymba's 3 full-attn
    layers, per-layer rope theta) ride along as scan inputs;
  * the VLM's sparse cross-attention layers are grouped into uniform
    *superblocks* (cadence-1 dense layers + 1 cross layer) so cross-attn
    params exist only where used;
  * enc-dec (whisper/t5) runs a separate encoder scan; every decoder layer
    carries cross-attention uniformly.

All functions are pure; ``mesh`` is threaded for MoE expert parallelism and
activation sharding constraints.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.efta import FTReport
from repro.models import ssm as ssm_lib
from repro.models.attention import (KVCache, PagedKVCache, attn_apply,
                                    attn_init, init_cache)
from repro.models.layers import (embed_apply, embed_init, learned_pos_init,
                                 matmul, mlp_apply, mlp_init, norm_apply,
                                 norm_init, unembed)
from repro.models.moe import moe_apply, moe_init

DP_AXES = ("pod", "data")


def shard_act(x, mesh, spec=None):
    if mesh is None:
        return x
    dp = tuple(a for a in DP_AXES if a in mesh.shape)
    if not dp:
        return x
    if spec is None:
        spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# per-layer flags
# ---------------------------------------------------------------------------

def layer_flags(cfg: ModelConfig) -> dict[str, np.ndarray]:
    """Static per-layer arrays: is_global (full attention) and rope theta."""
    n = cfg.num_layers
    a = cfg.attn
    is_global = np.ones((n,), np.bool_)
    theta = np.full((n,), a.rope_theta if a else 1e4, np.float32)
    if a is not None and a.sliding_window is not None:
        if a.global_every:
            is_global = (np.arange(n) % a.global_every) == (a.global_every - 1)
        elif cfg.family == "hybrid":
            # hymba: full attention at first / middle / last layers
            is_global = np.zeros((n,), np.bool_)
            for i in (0, n // 2, n - 1):
                is_global[i] = True
        else:
            is_global = np.zeros((n,), np.bool_)
        theta = np.where(is_global, 1e6 if a.global_every else a.rope_theta,
                         a.rope_theta).astype(np.float32)
    return {"is_global": is_global, "theta": theta}


# ---------------------------------------------------------------------------
# block init/apply (uniform within a model; selected by family)
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, *, cross: bool = False,
                causal: bool = True, kind: Optional[str] = None):
    kind = kind or cfg.family
    d, dtype = cfg.d_model, jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    if kind == "ssm":  # rwkv6
        p["norm1"] = norm_init(cfg.norm, d, dtype)
        p["time_mix"] = ssm_lib.rwkv6_init(ks[0], d, cfg.ssm, dtype)
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        return p
    p["norm1"] = norm_init(cfg.norm, d, dtype)
    p["attn"] = attn_init(ks[0], d, cfg.attn, dtype)
    if kind == "hybrid":
        p["mamba"] = ssm_lib.mamba_init(ks[1], d, cfg.ssm, dtype)
    if cross:
        p["norm_x"] = norm_init(cfg.norm, d, dtype)
        p["cross"] = attn_init(ks[2], d, cfg.attn, dtype, cross=True)
    p["norm2"] = norm_init(cfg.norm, d, dtype)
    if kind == "moe":
        p["moe"] = moe_init(ks[3], d, cfg.moe, dtype)
        if cfg.moe.num_shared_experts:
            p["shared"] = mlp_init(ks[4], d, cfg.moe.shared_d_ff, dtype,
                                   glu=cfg.glu)
        if cfg.moe.dense_d_ff:
            p["dense_res"] = mlp_init(ks[5], d, cfg.moe.dense_d_ff, dtype,
                                      glu=cfg.glu)
    else:
        p["mlp"] = mlp_init(ks[3], d, cfg.d_ff, dtype, glu=cfg.glu)
    return p


def _block_apply(params, x, *, cfg: ModelConfig, flags, cache, mode,
                 positions, memory, mesh, kind: Optional[str] = None,
                 causal: bool = True, fault=None):
    """One transformer block. Returns (x, report, aux, new_cache)."""
    kind = kind or cfg.family
    rep = FTReport.zero()
    aux = jnp.float32(0)
    new_cache = cache

    if kind == "ssm":  # rwkv6: time-mix + channel-mix
        h, st = ssm_lib.rwkv6_time_mix(
            params["time_mix"], norm_apply(cfg.norm, params["norm1"], x),
            cfg.ssm, state=cache if cache is not None
            else ssm_lib.rwkv_state_init(x.shape[0], cfg.d_model, cfg.ssm,
                                         x.dtype))
        x = x + h
        h, st = ssm_lib.rwkv6_channel_mix(
            params["time_mix"], norm_apply(cfg.norm, params["norm2"], x),
            state=st)
        x = x + h
        return x, rep, aux, (st if cache is not None else None)

    a = cfg.attn
    is_global = flags["is_global"]
    theta = flags["theta"]
    window = a.sliding_window
    acfg = dataclasses.replace(a, causal=causal)
    # per-layer window selection rides on a traced bool: implemented by
    # passing window and masking with where on the efta mask path would break
    # static masks, so we compute attention with the layer's static-ish flag
    # via lax.cond-free arithmetic: window=None case handled by huge window.
    eff_window = None
    if window is not None:
        big = 1 << 30
        eff_window = jnp.where(is_global, big, window)

    h_in = norm_apply(cfg.norm, params["norm1"], x)
    attn_cache = cache["attn"] if isinstance(cache, dict) else None
    acfg2 = dataclasses.replace(acfg, rope_theta=theta)
    h, rep_a, new_attn_cache = attn_apply(
        params["attn"], h_in, acfg=acfg2, ft=cfg.ft,
        window=eff_window, positions=positions, cache=attn_cache, mode=mode,
        fault=fault, mesh=mesh)
    rep = rep.merge(rep_a)

    if kind == "hybrid":
        mstate = cache["mamba"] if isinstance(cache, dict) else None
        hm, new_mstate = ssm_lib.mamba_apply(params["mamba"], h_in, cfg.ssm,
                                             state=mstate)
        h = 0.5 * (h + hm)
    x = x + h

    if "cross" in params:
        hx = norm_apply(cfg.norm, params["norm_x"], x)
        cross_cache = cache["attn"] if isinstance(cache, dict) else None
        hx, rep_x, cc = attn_apply(
            params["cross"], hx, acfg=dataclasses.replace(acfg, causal=False),
            ft=cfg.ft, positions=positions,
            cache=cross_cache, mode=mode, kv_x=memory, cross=True, mesh=mesh)
        rep = rep.merge(rep_x)
        if cc is not None and isinstance(cache, dict):
            new_attn_cache = new_attn_cache._replace(ck=cc.ck, cv=cc.cv) \
                if new_attn_cache is not None else cc
        x = x + hx

    h2 = norm_apply(cfg.norm, params["norm2"], x)
    if kind == "moe":
        y, aux = moe_apply(params["moe"], h2, cfg.moe, act=cfg.act, mesh=mesh,
                           mode=mode)
        if "shared" in params:
            y = y + mlp_apply(params["shared"], h2, act=cfg.act, glu=cfg.glu,
                              ff_abft=cfg.ft.ff_abft)
        if "dense_res" in params:
            y = y + mlp_apply(params["dense_res"], h2, act=cfg.act,
                              glu=cfg.glu, ff_abft=cfg.ft.ff_abft)
    else:
        y = mlp_apply(params["mlp"], h2, act=cfg.act, glu=cfg.glu,
                      ff_abft=cfg.ft.ff_abft)
    x = x + y

    if isinstance(cache, dict):
        new_cache = dict(cache)
        new_cache["attn"] = new_attn_cache
        if kind == "hybrid":
            new_cache["mamba"] = new_mstate
    return x, rep, aux, new_cache


# ---------------------------------------------------------------------------
# model: init
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, one_init):
    keys = jax.random.split(key, n)
    return jax.vmap(one_init)(keys)


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.attn is not None and cfg.attn.pos == "learned":
        params["pos"] = learned_pos_init(ks[1], max(cfg.max_seq, 64),
                                         cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model,
                                       dtype)

    cross_every = cfg.cross_attn_every
    if cfg.family == "vlm" and cross_every:
        n_super = cfg.num_layers // cross_every
        params["blocks"] = _stack_init(
            ks[3], n_super,
            lambda k: {
                "dense": _stack_init(
                    jax.random.fold_in(k, 0), cross_every - 1,
                    lambda kk: _block_init(kk, cfg, kind="dense")),
                "cross_blk": _block_init(jax.random.fold_in(k, 1), cfg,
                                         cross=True, kind="dense"),
            })
    elif cfg.family in ("audio", "encdec"):
        params["encoder"] = _stack_init(
            ks[4], cfg.encoder_layers,
            lambda k: _block_init(k, cfg, kind="dense", causal=False))
        params["enc_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
        params["blocks"] = _stack_init(
            ks[3], cfg.num_layers,
            lambda k: _block_init(k, cfg, cross=True, kind="dense"))
    elif cfg.family == "encoder":
        params["blocks"] = _stack_init(
            ks[3], cfg.num_layers,
            lambda k: _block_init(k, cfg, kind="dense", causal=False))
    else:
        params["blocks"] = _stack_init(
            ks[3], cfg.num_layers, lambda k: _block_init(k, cfg))
    return params


# ---------------------------------------------------------------------------
# model: forward
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_blocks(params_stack, x, *, cfg, flags_np, cache_stack, mode,
                 positions, memory, mesh, kind=None, causal=True, fault=None):
    """lax.scan over stacked block params (+ optional stacked caches)."""
    flags_arrs = {k: jnp.asarray(v) for k, v in flags_np.items()}
    have_cache = cache_stack is not None

    sp_spec = None
    if cfg.seq_parallel and mesh is not None and "model" in mesh.shape:
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        sp_spec = P(dp if dp else None, "model", None)

    def body(carry, inp):
        x, rep = carry
        if have_cache:
            bp, fl, cch = inp
        else:
            bp, fl = inp
            cch = None
        x = shard_act(x, mesh, sp_spec)
        x, rep_b, aux, new_c = _block_apply(
            bp, x, cfg=cfg, flags=fl, cache=cch, mode=mode,
            positions=positions, memory=memory, mesh=mesh, kind=kind,
            causal=causal, fault=fault)
        return (x, rep.merge(rep_b)), (aux, new_c) if have_cache else (aux,)

    body = _maybe_remat(body, cfg)
    n = jax.tree.leaves(params_stack)[0].shape[0]
    flags_stack = {k: (v if v.shape and v.shape[0] == n else
                       jnp.broadcast_to(v, (n,) + v.shape))
                   for k, v in flags_arrs.items()}
    xs = (params_stack, flags_stack, cache_stack) if have_cache else (
        params_stack, flags_stack)
    rep0 = FTReport.zero()
    if have_cache and isinstance(cache_stack, dict) and \
            isinstance(cache_stack.get("attn"), PagedKVCache):
        # paged decode reports per request: carry a (B, 5) report so the
        # engine sees per-slot detections, as the vmapped path does
        rep0 = FTReport(jnp.zeros((x.shape[0], 5), jnp.int32),
                        jnp.zeros((x.shape[0], 5), jnp.int32),
                        jnp.zeros((3,), jnp.float32))
    (x, rep), ys = jax.lax.scan(body, (x, rep0), xs,
                                unroll=True if not cfg.scan_layers else 1)
    aux = jnp.sum(ys[0])
    new_cache = ys[1] if have_cache else None
    return x, rep, aux, new_cache


def forward(params, cfg: ModelConfig, batch: dict, *, mesh=None,
            cache=None, mode: str = "train", fault=None):
    """Returns (logits f32 (B, S, V), FTReport, aux_loss, new_cache).

    ``fault`` is a :class:`repro.core.fault.FaultSpec` injected into every
    decoder self-attention call (the SEU strikes each attention layer's
    matching (site, kv-block) — a superset of the paper's single-layer SEU,
    so detection/correction coverage is exercised at least as hard).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_apply(params["embed"], tokens)
    if cache is not None and mode == "decode" and cfg.family != "ssm":
        pos0 = _cache_pos(cache)
        # paged caches decode natively batched over ragged requests: the
        # position base is per-request (B,), making positions (B, S)
        base = pos0[:, None] if pos0.ndim else pos0
        positions = base + jnp.arange(s, dtype=jnp.int32)
    else:
        positions = jnp.arange(s, dtype=jnp.int32)
    if "pos" in params:
        pe = jnp.take(params["pos"]["pos"],
                      jnp.minimum(positions, params["pos"]["pos"].shape[0] - 1),
                      axis=0).astype(x.dtype)
        x = x + (pe if positions.ndim == 2 else pe[None, :, :])
    x = shard_act(x, mesh)

    memory = None
    rep = FTReport.zero()
    aux = jnp.float32(0)
    flags = layer_flags(cfg)

    if cfg.family in ("audio", "encdec"):
        if cache is not None and mode == "decode":
            memory = None  # cross K/V live in the cache
        else:
            if "frontend" in batch:           # audio: precomputed frames (stub)
                enc_x = batch["frontend"].astype(x.dtype)
            else:                              # t5: token encoder
                enc_x = embed_apply(params["embed"], batch["enc_tokens"])
            enc_flags = {"is_global": np.ones((cfg.encoder_layers,), bool),
                         "theta": np.full((cfg.encoder_layers,),
                                          cfg.attn.rope_theta, np.float32)}
            enc_pos = jnp.arange(enc_x.shape[1], dtype=jnp.int32)
            enc_x = shard_act(enc_x, mesh)
            enc_x, rep_e, _, _ = _scan_blocks(
                params["encoder"], enc_x, cfg=cfg, flags_np=enc_flags,
                cache_stack=None, mode="train", positions=enc_pos,
                memory=None, mesh=mesh, kind="dense", causal=False)
            memory = norm_apply(cfg.norm, params["enc_norm"], enc_x)
            rep = rep.merge(rep_e)
    elif cfg.family == "vlm":
        memory = batch["frontend"].astype(x.dtype) if "frontend" in batch else None

    if cfg.family == "vlm" and cfg.cross_attn_every:
        ce = cfg.cross_attn_every
        n_super = cfg.num_layers // ce

        def super_body(carry, inp):
            x, rep = carry
            sp, cch = inp if cache is not None else (inp[0], None)
            aux_t = jnp.float32(0)
            new_cs = []
            for i in range(ce - 1):
                sub = jax.tree.map(lambda t, i=i: t[i], sp["dense"])
                c_i = (jax.tree.map(lambda t, i=i: t[i], cch["dense"])
                       if cch is not None else None)
                x = shard_act(x, mesh)
                x, rb, a_i, nc = _block_apply(
                    sub, x, cfg=cfg, flags={"is_global": jnp.bool_(True),
                                            "theta": jnp.float32(
                                                cfg.attn.rope_theta)},
                    cache=c_i, mode=mode, positions=positions, memory=None,
                    mesh=mesh, kind="dense", fault=fault)
                rep = rep.merge(rb)
                aux_t += a_i
                new_cs.append(nc)
            c_x = cch["cross_blk"] if cch is not None else None
            x, rb, a_i, nc_x = _block_apply(
                sp["cross_blk"], x, cfg=cfg,
                flags={"is_global": jnp.bool_(True),
                       "theta": jnp.float32(cfg.attn.rope_theta)},
                cache=c_x, mode=mode, positions=positions, memory=memory,
                mesh=mesh, kind="dense")
            rep = rep.merge(rb)
            aux_t += a_i
            new_c = None
            if cache is not None:
                new_c = {"dense": jax.tree.map(
                    lambda *ts: jnp.stack(ts), *new_cs), "cross_blk": nc_x}
            return (x, rep), (aux_t, new_c) if cache is not None else (aux_t,)

        super_body = _maybe_remat(super_body, cfg)
        xs = (params["blocks"], cache) if cache is not None else (
            params["blocks"],)
        (x, rep2), ys = jax.lax.scan(super_body, (x, rep), xs,
                                     unroll=True if not cfg.scan_layers else 1)
        rep = rep2
        aux = jnp.sum(ys[0])
        new_cache = ys[1] if cache is not None else None
    else:
        kind = None
        causal = cfg.family != "encoder"
        if cfg.family in ("audio", "encdec"):
            kind = "dense"
        x, rep_b, aux, new_cache = _scan_blocks(
            params["blocks"], x, cfg=cfg, flags_np=flags, cache_stack=cache,
            mode=mode, positions=positions, memory=memory, mesh=mesh,
            kind=kind, causal=causal, fault=fault)
        rep = rep.merge(rep_b)

    x = norm_apply(cfg.norm, params["final_norm"], x)
    x = shard_act(x, mesh)
    table = params.get("lm_head", params["embed"])["table"]
    logits = unembed(params["embed"], x, table=table)
    if mesh is not None and "model" in mesh.shape:
        dp = tuple(a for a in DP_AXES if a in mesh.shape)
        logits = shard_act(logits, mesh, P(dp, None, "model"))
    return logits, rep, aux, new_cache


def _cache_pos(cache) -> jax.Array:
    """Extract the position counter from a stacked cache pytree: a scalar
    for contiguous :class:`KVCache` rows, a per-request (B,) vector for the
    paged block pool (every layer shares one table, so layer 0's row is
    authoritative)."""
    def find(c):
        if isinstance(c, PagedKVCache):
            # stacked (L, B) -> (B,): per-request, stays a vector
            return c.pos[0] if c.pos.ndim > 1 else c.pos
        if isinstance(c, KVCache):
            return c.pos.reshape(-1)[0]
        if isinstance(c, dict):
            for v in c.values():
                r = find(v)
                if r is not None:
                    return r
        if isinstance(c, (list, tuple)) and not hasattr(c, "_fields"):
            for v in c:
                r = find(v)
                if r is not None:
                    return r
        if hasattr(c, "_fields"):  # other NamedTuples (ssm states) — no pos
            return None
        return None

    p = find(cache)
    if p is None:
        raise ValueError("cache has no position counter")
    return p
