"""AdamW from scratch — functional, sharding-transparent, with optional
low-precision moment storage (bf16) for the 1T-class archs.

Moments inherit the parameters' sharding (FSDP x TP), which is ZeRO-style
optimizer-state sharding for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


class AdamWState(NamedTuple):
    m: object
    v: object
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None   # None = same as param; "bfloat16" = low-mem

    def _sdtype(self, p):
        return jnp.dtype(self.state_dtype) if self.state_dtype else p.dtype

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self._sdtype(p))
        return AdamWState(
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params):
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * jnp.square(gf)
            mhat = mf / b1c
            vhat = vf / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        new = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([t[0] for t in new])
        new_m = treedef.unflatten([t[1] for t in new])
        new_v = treedef.unflatten([t[2] for t in new])
        return new_p, AdamWState(new_m, new_v, count)


def warmup_cosine(peak: float, *, warmup: int = 100, total: int = 10000,
                  floor: float = 0.1):
    def schedule(step):
        stepf = step.astype(jnp.float32)
        warm = stepf / max(warmup, 1)
        prog = jnp.clip((stepf - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(stepf < warmup, warm, cos)
    return schedule
