"""Core EFTA library — the paper's contribution as composable JAX modules."""
from repro.core.checksum import (
    Checksums,
    LOG_PROD_FLOOR,
    PAPER_STRIDE,
    TPU_STRIDE,
    block_fold_bad,
    encode_cols,
    encode_kv,
    encode_kv_tile,
    fold1,
    fold2,
    foldprod,
    kv_block_threshold,
    verify_and_correct,
    verify_block,
    verify_product,
    verify_product_log,
)
from repro.core.efta import EFTAConfig, FTReport, efta_attention, efta_mha, reference_attention
from repro.core.decoupled import decoupled_ft_attention, decoupled_memory_bytes
from repro.core.abft_gemm import abft_matmul, tensor_abft_matmul
from repro.core.fault import FaultSpec, Site, inject, random_fault
from repro.core.campaign import (CampaignResult, KVCampaignResult, SiteTally,
                                 DEFAULT_SITES, run_campaign, run_kv_campaign)
