"""Unified multi-token paged serving: the fused engine's mixed prefill +
decode batched step (chunked prefill folded into decode), the scheduler's
chunk budget, repair through the unified program, the compile-count
regression guard, decode-filled prefix registration, and the stamped-policy
background scrub.

The acceptance bar (ISSUE 4): ``PagedServeEngine(kernel="fused")`` serves
prefill, extend, repair and decode through the unified multi-token kernel
with zero calls into the per-bucket ``_prefill``/``_extend`` jits; mixed
prefill+decode batches are token-identical to the gather engine on the
parity matrix; fault campaigns through the chunked path report zero silent
corruptions; and the engine compiles at most two step programs regardless
of prompt lengths.
"""
import numpy as np
import pytest

from repro.serve.scheduler import ContinuousBatchingScheduler, Request


# ---------------------------------------------------------------------------
# scheduler chunk budget (no jax)
# ---------------------------------------------------------------------------

def _req(rid, admit_order):
    r = Request(rid=rid, prompt=np.asarray([1], np.int32), max_new_tokens=1)
    r.admit_order = admit_order
    return r


@pytest.mark.quick
def test_plan_chunks_decodes_never_starve_and_budget_is_fcfs():
    sched = ContinuousBatchingScheduler(4, chunk_budget=6)
    a, b, c = _req(0, 0), _req(1, 1), _req(2, 2)
    # a decodes (1 pending token), b and c are mid-prefill
    grants = sched.plan_chunks([(a, 1), (b, 30), (c, 30)], chunk_size=8)
    assert grants[a.rid] == 1            # decode granted outside the budget
    # b (earlier admission) drains the budget before c sees any surplus
    assert grants[b.rid] == 1 + 6
    assert grants[c.rid] == 1
    # unbounded budget: everyone gets a full chunk (capped at chunk_size)
    sched.chunk_budget = None
    grants = sched.plan_chunks([(a, 1), (b, 30), (c, 5)], chunk_size=8)
    assert grants == {a.rid: 1, b.rid: 8, c.rid: 5}


@pytest.mark.quick
def test_plan_chunks_zero_remaining_gets_zero():
    sched = ContinuousBatchingScheduler(2, chunk_budget=None)
    a, b = _req(0, 0), _req(1, 1)
    grants = sched.plan_chunks([(a, 0), (b, 3)], chunk_size=4)
    assert grants == {a.rid: 0, b.rid: 3}


# ---------------------------------------------------------------------------
# engine level (jax; gpt2-smoke)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("gpt2-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    return cfg, model, params, rng


def _paged(model, params, **kw):
    from repro.serve import PagedServeEngine
    kw.setdefault("n_slots", 3)
    kw.setdefault("cache_len", 48)
    kw.setdefault("block_size", 16)
    return PagedServeEngine(model, params, **kw)


def _forbid_bucketed_paths(eng):
    """The acceptance criterion: the unified engine must never touch the
    per-bucket prefill/extend jits — prefill, extend, repair and decode all
    go through the one multi-token fused program."""
    def boom(*a, **k):
        raise AssertionError("unified engine called a bucketed "
                             "prefill/extend jit")
    eng._prefill = boom
    eng._extend = boom
    eng._gather_ctx = boom
    eng._scatter = boom


def test_unified_mixed_batches_token_identical_to_gather(setup):
    """Parity matrix: ragged prompt lengths straddling chunk and block
    edges, several chunk widths, more requests than slots (admission mixes
    prefill chunks into live decode batches) — the unified fused engine must
    emit exactly the gather engine's tokens, with zero bucketed-jit calls
    and zero false positives."""
    cfg, model, params, rng = setup
    lengths = [3, 9, 16, 17, 25, 31, 40]
    steps = [5, 4, 7, 3, 6, 4, 5]
    prompts = [rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)
               for t in lengths]
    ref_eng = _paged(model, params)                 # gather baseline (PR 3)
    for p, s in zip(prompts, steps):
        ref_eng.submit(p, max_new_tokens=s)
    ref = ref_eng.run()

    for chunk in (16, 32):
        eng = _paged(model, params, kernel="fused", chunk_size=chunk)
        _forbid_bucketed_paths(eng)
        for p, s in zip(prompts, steps):
            eng.submit(p, max_new_tokens=s)
        got = eng.run()
        assert set(got) == set(ref)
        for rid in ref:
            np.testing.assert_array_equal(
                got[rid], ref[rid], err_msg=f"chunk={chunk} rid={rid}")
        assert eng.paged_stats.chunked_prefill_tokens > 0
        assert eng.paged_stats.kv_detected_blocks == 0
        assert eng.stats.steps < sum(steps) + len(lengths)  # actually mixed


def test_unified_engine_compiles_at_most_two_step_programs(setup):
    """The compile-count regression guard: any mix of prompt lengths runs
    through exactly two compiled programs (chunk width + decode width) —
    the one-per-prompt-bucket scheme this PR retires would compile one per
    distinct padded length."""
    cfg, model, params, rng = setup
    eng = _paged(model, params, kernel="fused", chunk_size=16)
    for t in (3, 5, 9, 14, 17, 23, 26, 31, 40, 44):
        eng.submit(rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32),
                   max_new_tokens=3)
    eng.run()
    n_programs = eng._step_fused._cache_size()
    assert n_programs <= 2, \
        f"unified step compiled {n_programs} programs for 10 prompt lengths"


def test_chunk_budget_prevents_head_of_line_blocking(setup):
    """A long prompt prefilling under a small chunk budget must not stall a
    decoding request: the decode gets its token every step while the prompt
    trickles in, so the short request finishes before the long one even
    starts generating."""
    cfg, model, params, rng = setup
    short = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    long_p = rng.integers(0, cfg.vocab_size, (40,)).astype(np.int32)

    eng = _paged(model, params, n_slots=2, kernel="fused",
                 chunk_size=16, chunk_budget=4)
    r_short = eng.submit(short, max_new_tokens=6)
    eng.step()                                   # short admitted, decoding
    r_long = eng.submit(long_p, max_new_tokens=2)
    short_req = next(r for r in eng.scheduler.active_rows()
                     if r.rid == r_short)
    gen_trace = []
    while not short_req.is_done():
        eng.step()
        gen_trace.append(short_req.num_generated)
    long_req = next((r for r in eng.scheduler.active_rows()
                     if r.rid == r_long), None)
    # decode advanced every single step despite the 40-token prompt...
    assert gen_trace == list(range(gen_trace[0], gen_trace[0] + len(gen_trace)))
    # ...which is still mid-prefill under its 4-token/step budget
    assert long_req is not None and long_req.num_generated == 0
    outs = eng.run()

    # and the budgeted interleaving changed nothing about the tokens
    ref_eng = _paged(model, params, n_slots=2, kernel="fused")
    ra = ref_eng.submit(short, max_new_tokens=6)
    rb = ref_eng.submit(long_p, max_new_tokens=2)
    ref = ref_eng.run()
    np.testing.assert_array_equal(outs[r_short], ref[ra])
    np.testing.assert_array_equal(outs[r_long], ref[rb])


def test_unified_repair_reuses_the_step_program(setup):
    """Satellite: block repair routes through the SAME unified chunked
    program as prefill/decode — detect -> repair -> token-identical without
    compiling anything new, even when the repair happens under pool
    pressure mid-generation."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)
    clean = _paged(model, params, n_slots=2, kernel="fused", chunk_size=16)
    rc = clean.submit(prompt, max_new_tokens=8)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, kernel="fused", chunk_size=16)
    _forbid_bucketed_paths(eng)
    rid = eng.submit(prompt, max_new_tokens=8)
    eng.step()          # prefill chunk 1 (chunk-width program compiles)
    eng.step()          # prefill chunk 2 + first sample
    eng.step()          # decode (width-1 program compiles)
    programs_before = eng._step_fused._cache_size()
    assert programs_before == 2
    req = list(eng.scheduler.active_rows())[0]
    eng.inject_kv_fault(layer=1, block=req.block_ids[0], head=0, row=3,
                        col=5, bit=27, into="v")
    out = eng.run()[rid]
    np.testing.assert_array_equal(out, ref)
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks == 1
    assert eng._step_fused._cache_size() == programs_before


def test_kv_campaign_through_chunked_prefill(setup):
    """Site.KV SEU campaign with prompts longer than the chunk width, so
    flips strike mid-prefill state and the detect -> repair -> token-
    identical contract is exercised through the chunked kernel path."""
    from repro.core import run_kv_campaign
    r = run_kv_campaign(n_trials=3, seed=11, kernel="fused", n_requests=2,
                        cache_len=64, max_prompt=40, gen=4, chunk_size=16)
    assert r.n_trials == 3
    assert r.detected == 3, r.format_table()
    assert r.undetected == 0
    assert r.repaired_blocks >= 3
    assert r.mismatched_requests == 0, r.format_table()


def test_compute_site_seu_during_chunked_prefill(setup):
    """An EFTA compute-site SEU striking a step whose batch is prefilling a
    chunk: detected by the in-kernel scheme, retried/corrected, and the
    final tokens equal a clean run's."""
    from repro.core import FaultSpec, Site
    from repro.serve import batch_faults
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (40,)).astype(np.int32)

    clean = _paged(model, params, n_slots=2, kernel="fused", chunk_size=16)
    rc = clean.submit(prompt, max_new_tokens=4)
    ref = clean.run()[rc]

    eng = _paged(model, params, n_slots=2, kernel="fused", chunk_size=16)
    rid = eng.submit(prompt, max_new_tokens=4)
    # steps 0-2 are chunked prefill (40 tokens / 16-chunk); strike two
    faults = {0: batch_faults(2, {0: FaultSpec.single(
                  Site.GEMM2, block=0, head=1, row=0, col=3, bit=27)}),
              1: batch_faults(2, {0: FaultSpec.single(
                  Site.GEMM1, block=1, head=2, row=0, col=5, bit=26)})}
    out = eng.run(faults_by_step=faults)[rid]
    np.testing.assert_array_equal(out, ref)
    st = eng.telemetry.requests[rid]
    assert sum(st.detected[:5]) >= 1
    assert st.detected[5] == 0          # compute faults, not memory faults


@pytest.mark.parametrize("kernel", ["gather", "fused"])
def test_decode_filled_blocks_register_in_prefix_cache(setup, kernel):
    """Satellite: blocks completed by *decode* join the token-hash chain, so
    resampling the same prompt + continuation prefix (n-best / self-
    consistency) hits cache past the prompt. Before this PR only prompt
    blocks registered and the continuation re-prefilled every time."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    eng = _paged(model, params, n_slots=2, cache_len=64, num_blocks=16,
                 kernel=kernel)
    r0 = eng.submit(prompt, max_new_tokens=20)
    first = eng.run()[r0]

    # n-best continuation: a follow-up request whose prompt replays the
    # original prompt plus the generated continuation
    p2 = np.concatenate([prompt, first]).astype(np.int32)
    hit_before = eng.pool.prefix.stats.hit_tokens
    r1 = eng.submit(p2, max_new_tokens=2)
    eng.run()
    hits = eng.pool.prefix.stats.hit_tokens - hit_before
    # 36 resident tokens -> blocks 0 (prompt) and 1 (decode-filled) both hit
    assert hits >= 32, f"continuation prefix only hit {hits} tokens"


def test_scrub_bounds_stamped_deferred_detection(setup):
    """Satellite: the background scrub closes the stamped policy's deferred-
    detection window. The exact scenario the regression test pins as missed
    (a flip in a verified-and-untouched block) is caught by the next scrub
    pass and repaired, instead of hiding until the block's next write."""
    cfg, model, params, rng = setup
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)

    def poisoned(**kw):
        eng = _paged(model, params, n_slots=2, kv_verify="stamped", **kw)
        eng.submit(prompt, max_new_tokens=4)
        eng.step()
        req = list(eng.scheduler.active_rows())[0]
        # block 0 is non-tail (pos = 20 > block_size): stamped-verified,
        # skipped by the read-time selector
        eng.inject_kv_fault(layer=0, block=req.block_ids[0], head=0,
                            row=2, col=3, bit=27, into="k")
        eng.run()
        return eng

    missed = poisoned()                              # the pinned tradeoff
    assert missed.paged_stats.kv_detected_blocks == 0

    eng = poisoned(scrub_interval=1, scrub_batch=4)  # scrub bounds it
    assert eng.paged_stats.kv_scrubbed_blocks > 0
    assert eng.paged_stats.kv_detected_blocks == 1
    assert eng.paged_stats.kv_repaired_blocks >= 1


def test_scrub_covers_parked_prefix_blocks(setup):
    """Satellite (ISSUE 5): the background scrub draws from *parked*
    prefix-cache blocks after the live tables. A bit flip landing in a
    shared-prefix block while it sits parked (ref == 0 — in no live table,
    so read-time verification never reaches it) is caught by the next scrub
    pass, the poisoned cache entry is discarded, and the next admission of
    the same prefix takes a clean miss instead of gathering corruption."""
    cfg, model, params, rng = setup
    shared = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    tail = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    prompt = np.concatenate([shared, tail])

    eng = _paged(model, params, cache_len=64, num_blocks=16,
                 kv_verify="stamped", scrub_interval=1, scrub_batch=2)
    eng.submit(prompt, max_new_tokens=2)
    eng.run()                                    # finish -> blocks park
    parked = eng.pool.blocks.parked_blocks()
    assert parked, "finished request's registered blocks should park"
    eng.inject_kv_fault(layer=0, block=parked[0], head=0, row=3, col=1,
                        bit=27, into="k")
    det0 = eng.paged_stats.kv_detected_blocks
    # an unrelated long-running request drives steps (and scrub passes)
    # while the poisoned block stays parked — no admission touches it
    eng.submit(rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32),
               max_new_tokens=6)
    steps = 0
    while eng.scheduler.has_work and \
            eng.paged_stats.kv_detected_blocks == det0:
        eng.step()
        steps += 1
        assert steps < 20, "scrub never reached the parked block"
    assert eng.paged_stats.kv_detected_blocks == det0 + 1
    assert parked[0] not in eng.pool.blocks.parked_blocks()
    eng.run()

    # the same shared prefix admits cleanly (cache miss, fresh prefill) and
    # is token-identical to an uncorrupted engine
    r2 = eng.submit(prompt, max_new_tokens=2)
    out = eng.run()[r2]
    ref_eng = _paged(model, params, cache_len=64, num_blocks=16)
    rr = ref_eng.submit(prompt, max_new_tokens=2)
    np.testing.assert_array_equal(out, ref_eng.run()[rr])


@pytest.mark.quick
def test_unified_quick_smoke(setup):
    """Quick-tier guard: one mixed batch (a prefilling prompt + a decoding
    request), zero bucketed-jit calls, tokens identical to gather."""
    cfg, model, params, rng = setup
    pa = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (26,)).astype(np.int32)
    ref_eng = _paged(model, params, n_slots=2)
    fused = _paged(model, params, n_slots=2, kernel="fused", chunk_size=16)
    _forbid_bucketed_paths(fused)
    ids = {}
    for eng, tag in ((ref_eng, "ref"), (fused, "fused")):
        ids[tag] = [eng.submit(pa, max_new_tokens=4),
                    eng.submit(pb, max_new_tokens=3)]
    ref = ref_eng.run()
    got = fused.run()
    for (ra, rb), (ga, gb) in [(ids["ref"], ids["fused"])]:
        np.testing.assert_array_equal(got[ga], ref[ra])
        np.testing.assert_array_equal(got[gb], ref[rb])
    assert fused.paged_stats.chunked_prefill_tokens > 0
